"""Paper Fig. 3: relation between expiry time and executed steps.

The paper interrupts an ESP32 with a hardware timer; we simulate the
same protocol: a wall-clock deadline interrupts the anytime session (the
engine advances in single steps and checks the clock — the tightest
abort granularity the implementation supports), and we record the
normalized number of executed steps per configured expiry period.

Claim under test: steps executed grow ~linearly with the time budget,
justifying steps as the unit of progress for the rest of the evaluation.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_pipeline, runtime_for


def run(n_trees: int = 10, depth: int = 10, dataset: str = "adult",
        n_periods: int = 8, repeats: int = 3, verbose: bool = True):
    fa, pp, yor, te, yte = build_pipeline(dataset, n_trees, depth)
    rt = runtime_for(fa, pp, yor)
    rows = []
    for order_name in ("backward_squirrel", "depth", "breadth", "random"):
        total = rt.order(order_name).shape[0]
        # warm up (compile), then calibrate a full run to set expiry periods
        rt.session(te, order_name, chunk=1).run_to_completion()
        sess = rt.session(te, order_name, chunk=1)
        t0 = time.perf_counter()
        while sess.remaining:
            sess.advance(1)
        full_t = time.perf_counter() - t0
        for frac in np.linspace(0.08, 1.1, n_periods):
            expiry = full_t * frac
            done = []
            for _ in range(repeats):
                sess = rt.session(te, order_name, chunk=1)
                sess.advance_until(expiry * 1e3, chunk=1)
                done.append(sess.pos / total)
            rows.append({
                "order": order_name,
                "expiry_us": expiry * 1e6,
                "steps_norm": float(np.mean(done)),
            })
            if verbose:
                r = rows[-1]
                print(f"fig3,{r['order']},{r['expiry_us']:.0f},{r['steps_norm']:.3f}")
    # linearity check per order (paper: "largely linear relation")
    out = {"rows": rows}
    for name in ("backward_squirrel", "depth"):
        sub = [(r["expiry_us"], r["steps_norm"]) for r in rows
               if r["order"] == name and 0.005 < r["steps_norm"] < 0.995]
        if len(sub) >= 3:
            x, ynorm = np.array(sub).T
            r = np.corrcoef(x, ynorm)[0, 1]
            out[f"linearity_r_{name}"] = float(r)
            if verbose:
                print(f"fig3,linearity_r,{name},{r:.4f}")
    return out


if __name__ == "__main__":
    run()
