"""Shared benchmark utilities."""
from __future__ import annotations

import time


from repro.core import AnytimeForest, engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import AnytimeRuntime, ForestProgram, get_order_policy


def build_pipeline(dataset: str, n_trees: int, depth: int, seed: int = 0,
                   n_order: int = 500, n_test: int = 500):
    """dataset -> (forest arrays, path_probs on S_o, y_o, X_t, y_t)."""
    X, y = make_dataset(dataset, seed=seed)
    n_classes = int(y.max()) + 1
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=seed)
    rf = train_forest(tr, ytr, n_classes, n_trees=n_trees, max_depth=depth,
                      seed=seed)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:n_order])
    return fa, pp, yor[:n_order], te[:n_test], yte[:n_test]


def runtime_for(fa, pp, yor) -> AnytimeRuntime:
    """An AnytimeRuntime over a pipeline's precomputed quality table."""
    return AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))


def curve_for(fa, pp, yor, te, yte, order_name: str, seed: int = 0):
    order = get_order_policy(order_name, seed=seed).generate(pp, yor)
    return AnytimeForest(fa, order).accuracy_curve(te, yte)


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt
