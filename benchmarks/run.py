"""Benchmark driver: one module per paper figure + kernel micro-bench.

``python -m benchmarks.run [--fast]`` prints CSV-ish lines per benchmark
and writes reports/bench_results.json.  EXPERIMENTS.md cites these
numbers; the roofline/dry-run tables come from repro.launch.dryrun.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids (CI-sized)")
    ap.add_argument("--out", default="reports/bench_results.json")
    args = ap.parse_args()

    from benchmarks import (bench_fig3_time_vs_steps, bench_fig4_order_gen_runtime,
                            bench_fig5_steps_vs_accuracy, bench_fig6_nma,
                            bench_kernels)

    results = {}
    t0 = time.perf_counter()

    print("== Fig.3: expiry time vs executed steps ==", flush=True)
    results["fig3"] = bench_fig3_time_vs_steps.run(
        n_trees=6 if args.fast else 10, depth=6 if args.fast else 10,
        n_periods=5 if args.fast else 8, repeats=2 if args.fast else 3)

    print("== Fig.4: order generation runtime ==", flush=True)
    results["fig4"] = bench_fig4_order_gen_runtime.run(
        depth=6 if args.fast else 8,
        max_trees=6 if args.fast else 8,
        optimal_limit=4 if args.fast else 6)

    print("== Fig.5: steps vs accuracy ==", flush=True)
    results["fig5"] = bench_fig5_steps_vs_accuracy.run(
        n_trees=5 if args.fast else 6, depth=5 if args.fast else 6)

    print("== Fig.6: NMA across datasets ==", flush=True)
    results["fig6"] = bench_fig6_nma.run(
        datasets=["magic", "letter", "spambase"] if args.fast else None,
        small=(4, 4) if args.fast else (5, 4),
        large=(8, 6) if args.fast else (10, 8),
        seeds=(0,) if args.fast else (0, 1))

    print("== Kernel micro-benchmarks ==", flush=True)
    results["kernels"] = bench_kernels.run()

    results["total_s"] = time.perf_counter() - t0
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    def default(o):
        import numpy as np
        if isinstance(o, (np.floating, np.integer)):
            return o.item()
        if isinstance(o, np.ndarray):
            return o.tolist()
        return str(o)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, default=default)
    print(f"bench,total_s,{results['total_s']:.1f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
