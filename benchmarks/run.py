"""Benchmark driver: one module per paper figure + kernel/backend benches.

``python -m benchmarks.run [--fast]`` prints CSV-ish lines per benchmark
and writes reports/bench_results.json plus BENCH_nma.json (per-order NMA
from one vmapped ``AnytimeRuntime.evaluate_orders`` pass),
BENCH_serve.json (batched-vs-serial serving: requests/sec,
deadline-hit-rate, p99 steps-at-deadline), and BENCH_kernels.json
(fused-vs-scan and slot-kernel-vs-gather launch comparisons) — the
numbers regression-tracked across PRs.  EXPERIMENTS.md cites these numbers; the
roofline/dry-run tables come from repro.launch.dryrun.

``--smoke`` is the CI gate: reduced config, only the execution-backend
parity check (pallas/sharded vs the jnp-ref oracle — raises on
divergence, failing the build), the step-plan trace-count bound, the
kernel gate (fused-vs-scan >= 1.5x on TPU, bit-parity asserted in
interpret mode on CPU — BENCH_kernels.json), the NMA summary, and the
serving gate (batched AND threaded scheduling must beat the serial
per-request loop >= 3x at >= 99% deadline-hit-rate, and degrade
admission must dominate reject on hit-rate under overload, or the
build fails).

``--check-baseline`` additionally regression-gates the fresh results
against the committed BENCH_*.json files (benchmarks/baseline.py):
counts, parity, and the analytical kernel counters (launches, gather
bytes/step, resident bytes, tuned-selection speedup >= 1.0) always —
those are platform-independent and gate in interpret mode too;
wall-clock only where actually measured (interpret-mode kernel timings
are skipped).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _dump(path: str, payload) -> None:
    import numpy as np

    def default(o):
        if isinstance(o, (np.floating, np.integer)):
            return o.item()
        if isinstance(o, np.ndarray):
            return o.tolist()
        return str(o)

    if os.path.dirname(path):
        os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=default)
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids (CI-sized)")
    ap.add_argument("--smoke", action="store_true",
                    help="backend-parity gate + trace bound + kernels + NMA "
                         "only (fails on kernel-path regressions)")
    ap.add_argument("--out", default="reports/bench_results.json")
    ap.add_argument("--nma-out", default="BENCH_nma.json",
                    help="per-order NMA summary for cross-PR regression "
                         "tracking")
    ap.add_argument("--kernels-out", default="BENCH_kernels.json",
                    help="fused-vs-scan and slot-kernel-vs-gather kernel "
                         "comparison (gated >= 1.5x fused on TPU; "
                         "parity-asserted in interpret mode on CPU)")
    ap.add_argument("--serve-out", default="BENCH_serve.json",
                    help="batched/threaded-vs-serial serving summary "
                         "(requests/sec, deadline-hit-rate, p99 "
                         "steps-at-deadline, admission frontier)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail if fresh results regress vs the committed "
                         "BENCH_*.json baselines (counts/parity always; "
                         "wall-clock only where measured)")
    args = ap.parse_args()

    from benchmarks import bench_backends, bench_kernels, bench_serve

    baselines = None
    if args.check_baseline:
        # snapshot the committed baselines BEFORE the run rewrites the
        # same files: the gate compares against what the repo promises
        from benchmarks import baseline

        baselines = baseline.load_baselines()

    results = {}
    t0 = time.perf_counter()

    if not args.smoke:
        from benchmarks import (bench_fig3_time_vs_steps,
                                bench_fig4_order_gen_runtime,
                                bench_fig5_steps_vs_accuracy, bench_fig6_nma)

        print("== Fig.3: expiry time vs executed steps ==", flush=True)
        results["fig3"] = bench_fig3_time_vs_steps.run(
            n_trees=6 if args.fast else 10, depth=6 if args.fast else 10,
            n_periods=5 if args.fast else 8, repeats=2 if args.fast else 3)

        print("== Fig.4: order generation runtime ==", flush=True)
        results["fig4"] = bench_fig4_order_gen_runtime.run(
            depth=6 if args.fast else 8,
            max_trees=6 if args.fast else 8,
            optimal_limit=4 if args.fast else 6)

        print("== Fig.5: steps vs accuracy ==", flush=True)
        results["fig5"] = bench_fig5_steps_vs_accuracy.run(
            n_trees=5 if args.fast else 6, depth=5 if args.fast else 6)

        print("== Fig.6: NMA across datasets ==", flush=True)
        results["fig6"] = bench_fig6_nma.run(
            datasets=["magic", "letter", "spambase"] if args.fast else None,
            small=(4, 4) if args.fast else (5, 4),
            large=(8, 6) if args.fast else (10, 8),
            seeds=(0,) if args.fast else (0, 1))

    print("== Backend parity gate (pallas/sharded vs jnp-ref) ==", flush=True)
    results["backend_parity"] = bench_backends.run_parity(
        n_trees=3 if args.smoke else 4, depth=4 if args.smoke else 5)

    print("== Step-plan trace bound ==", flush=True)
    results["stepplan"] = bench_backends.run_stepplan_traces(
        n_trees=4 if args.smoke else 6, depth=8 if args.smoke else 12)

    print("== Kernels: fused/slot/depth variants + tuned selection "
          "(gated) ==", flush=True)
    # gated: fused multi-step launch >= 1.5x the scanned single-step path
    # on TPU; on every platform bit-parity across all registered impls,
    # depth-variant gather counters strictly below fused, and tuned
    # selection never slower than its conservative fallback
    results["kernels"] = bench_kernels.run(gate=True)
    _dump(args.kernels_out, results["kernels"])

    print("== Per-order NMA (evaluate_orders, vmapped) ==", flush=True)
    small = args.smoke or args.fast
    results["nma"] = bench_backends.run_nma(
        n_trees=4 if small else 6, depth=3 if small else 5)
    _dump(args.nma_out, results["nma"])

    print("== Serving: batched scheduler vs serial session loop ==",
          flush=True)
    # gated: batched >= 3x serial requests/sec at >= 99% hit-rate
    results["serve"] = bench_serve.run(
        n_trees=6 if small else 10, depth=5 if small else 6,
        capacity=8 if small else 16, n_requests=24 if small else 48)

    print("== Serving frontier: pooled tier under open-loop load "
          "(virtual time, gated >= 3x pool scaling) ==", flush=True)
    from benchmarks import loadgen

    # gated: the 4-pool knee (highest offered rate at >= 99% full-plan
    # completion inside deadline) must be >= 3x the single-pool knee
    results["serve"]["frontier"] = loadgen.run(
        n_requests=64 if small else 96)
    _dump(args.serve_out, results["serve"])

    results["total_s"] = time.perf_counter() - t0
    _dump(args.out, results)
    print(f"bench,total_s,{results['total_s']:.1f}")

    if args.check_baseline:
        failures = baseline.check_baselines(results, baselines)
        if failures:
            for msg in failures:
                print(f"bench,baseline,FAIL,{msg}")
            raise SystemExit(
                f"bench-regression gate: {len(failures)} failure(s) vs "
                "committed BENCH_*.json baselines")
        print("bench,baseline,ok")


if __name__ == "__main__":
    main()
