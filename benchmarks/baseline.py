"""Bench-regression gate: compare a fresh ``--smoke`` run against the
committed ``BENCH_nma.json`` / ``BENCH_serve.json`` / ``BENCH_kernels.json``
baselines and fail the build on regression.

What is compared, per the gate's contract:

* **counts and parity — always.**  Order coverage and NMA values
  (deterministic given the seeded smoke config), request/launch counts,
  hit-rates, and the degrade-dominates-reject admission frontier.
* **wall-clock — only where it was actually measured.**  Interpret-mode
  kernel timings (``platform != "tpu"``) are functional checks, not
  performance numbers, and are skipped; measured serving speedups are
  compared with a generous factor so machine-to-machine CI variance
  doesn't flake the build while order-of-magnitude regressions still
  fail it.

A failed gate means either a real regression (fix it) or an intentional
config/metric change (regenerate the ``BENCH_*.json`` files with
``python -m benchmarks.run --smoke`` and commit them alongside the
change).
"""
from __future__ import annotations

import json
import os
from typing import Optional

#: absolute tolerance for deterministic quality metrics (NMA values are
#: reproducible from the seeded smoke config up to float accumulation
#: differences across BLAS/platform builds)
NMA_ATOL = 2e-3
#: hit-rates may wobble by a request or two on loaded CI machines
HIT_RATE_TOL = 0.02
#: measured wall-clock speedups must stay within this factor of the
#: committed baseline (catches order-of-magnitude regressions, not noise)
WALL_CLOCK_FACTOR = 0.25
#: the pooled-tier knee-scaling gate (4 pools vs 1 at equal good-rate)
MIN_POOL_SCALING = 3.0
#: certified serving is a proof, not a percentile: a guaranteed=True
#: request that was admitted and then missed its deadline is a broken
#: contract, so the budget is zero on every platform, absolutely
GUARANTEED_MISS_BUDGET = 0


def _load(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def check_nma(fresh: dict, base: dict, failures: list[str]) -> None:
    fresh_nma, base_nma = fresh.get("nma", {}), base.get("nma", {})
    for name, ref in base_nma.items():
        got = fresh_nma.get(name)
        if got is None:
            failures.append(f"nma: order {name!r} missing from fresh run")
        elif abs(float(got) - float(ref)) > NMA_ATOL:
            failures.append(
                f"nma: {name} = {float(got):.6f}, baseline "
                f"{float(ref):.6f} (atol {NMA_ATOL})")


def check_serve(fresh: dict, base: dict, failures: list[str]) -> None:
    # counts: the smoke config and its coverage must match the baseline
    for key in ("n_requests", "capacity", "total_steps"):
        if fresh.get(key) != base.get(key):
            failures.append(
                f"serve: {key} = {fresh.get(key)}, baseline {base.get(key)} "
                "(config drift — regenerate BENCH_serve.json)")
    for mode in ("serial", "batched", "threaded"):
        f_mode, b_mode = fresh.get(mode, {}), base.get(mode, {})
        if f_mode.get("requests") != b_mode.get("requests"):
            failures.append(
                f"serve: {mode} served {f_mode.get('requests')} requests, "
                f"baseline {b_mode.get('requests')}")
        got = f_mode.get("deadline_hit_rate", 0.0)
        ref = b_mode.get("deadline_hit_rate", 0.0)
        if got < ref - HIT_RATE_TOL:
            failures.append(
                f"serve: {mode} hit-rate {got:.3f} below baseline {ref:.3f}")
    # the admission frontier: degrade must keep dominating reject
    f_over = fresh.get("overload", {})
    reject_hit = f_over.get("reject", {}).get("hit_rate", 0.0)
    degrade_hit = f_over.get("degrade", {}).get("hit_rate", 0.0)
    if degrade_hit <= reject_hit:
        failures.append(
            f"serve: overload degrade hit-rate {degrade_hit:.3f} no longer "
            f"dominates reject {reject_hit:.3f}")
    b_over = base.get("overload", {})
    ref_degrade = b_over.get("degrade", {}).get("hit_rate", 0.0)
    if degrade_hit < ref_degrade - HIT_RATE_TOL:
        failures.append(
            f"serve: overload degrade hit-rate {degrade_hit:.3f} below "
            f"baseline {ref_degrade:.3f}")
    # the certified contract: like MIN_POOL_SCALING this gates
    # absolutely, not relative to the baseline — the section must exist,
    # hold zero guaranteed misses, and prove the rejection side fired
    f_g = fresh.get("guaranteed")
    if f_g is None:
        failures.append("serve: fresh run produced no guaranteed section")
    else:
        misses = int(f_g.get("misses", 1))
        m_misses = int(f_g.get("metrics_misses", 1))
        if misses > GUARANTEED_MISS_BUDGET or m_misses > GUARANTEED_MISS_BUDGET:
            failures.append(
                f"serve: guaranteed deadline misses {misses} "
                f"(metrics {m_misses}) over the {GUARANTEED_MISS_BUDGET} "
                f"budget — certified admission admitted a request it "
                f"could not deliver")
        if int(f_g.get("rejected_infeasible", 0)) < 1:
            failures.append(
                "serve: certified admission rejected no provably-"
                "infeasible deadline — the pricing gate is not firing")
        for name, gb in (f_g.get("backends") or {}).items():
            if not gb.get("parity_vs_solo"):
                failures.append(
                    f"serve: guaranteed {name} deliveries lost bit-parity "
                    f"with the solo jnp-ref oracle")
            if gb.get("completed") != gb.get("requests"):
                failures.append(
                    f"serve: guaranteed {name} completed "
                    f"{gb.get('completed')}/{gb.get('requests')} full "
                    f"plans inside the certified deadline")
    # wall-clock — measured on every platform (this is real serving
    # throughput, not interpret-mode): generous factor, fail only on
    # order-of-magnitude regressions
    for key in ("speedup", "threaded_speedup"):
        got, ref = fresh.get(key), base.get(key)
        if got is not None and ref is not None:
            if float(got) < float(ref) * WALL_CLOCK_FACTOR:
                failures.append(
                    f"serve: {key} {float(got):.2f}x below "
                    f"{WALL_CLOCK_FACTOR}x baseline ({float(ref):.2f}x)")
    # the pooled-tier frontier: knee scaling is a RATIO of virtual-time
    # knees off one shared calibration, so it is machine-stable — the
    # >= 3x gate holds absolutely, not just relative to the baseline
    b_front = base.get("frontier")
    if b_front is not None:
        f_front = fresh.get("frontier")
        if f_front is None:
            failures.append("serve: fresh run produced no frontier section")
        else:
            scaling = float(f_front.get("pool_scaling", 0.0))
            if scaling < MIN_POOL_SCALING:
                failures.append(
                    f"serve: frontier pool_scaling {scaling:.2f}x below "
                    f"the {MIN_POOL_SCALING}x gate")
            if len(f_front.get("points", [])) < len(b_front.get("points", [])):
                failures.append(
                    f"serve: frontier covers {len(f_front.get('points', []))}"
                    f" rate points, baseline "
                    f"{len(b_front.get('points', []))}")
            # knee rates derive from this machine's calibrated step cost:
            # wall-clock comparison, generous factor
            for pools, ref_knee in b_front.get("knee_rps", {}).items():
                got_knee = f_front.get("knee_rps", {}).get(pools)
                if got_knee is None:
                    failures.append(
                        f"serve: frontier knee for {pools} pool(s) missing")
                elif float(got_knee) < float(ref_knee) * WALL_CLOCK_FACTOR:
                    failures.append(
                        f"serve: frontier knee({pools} pools) "
                        f"{float(got_knee):.0f} rps below "
                        f"{WALL_CLOCK_FACTOR}x baseline "
                        f"({float(ref_knee):.0f} rps)")


def check_kernels(fresh: dict, base: dict, failures: list[str]) -> None:
    # counts/parity always: launch counts and analytical gather/residency
    # counters are platform-independent — equality vs the baseline holds
    # in interpret mode too, so these gate on EVERY platform
    _COUNTER_KEYS = {
        "fused_vs_scan": ("launches_fused", "launches_scanned",
                          "gather_bytes_per_step", "resident_bytes"),
        "slot_vs_gather": ("launches_kernel", "gather_bytes_per_step",
                           "resident_bytes"),
        "depth_vs_fused": ("gather_bytes_per_step_depth",
                           "gather_bytes_per_step_fused"),
    }
    for section, keys in _COUNTER_KEYS.items():
        base_cases = base.get(section, [])
        fresh_cases = fresh.get(section, [])
        if len(fresh_cases) < len(base_cases):
            failures.append(
                f"kernels: {section} covers {len(fresh_cases)} cases, "
                f"baseline {len(base_cases)}")
            continue
        for ref, got in zip(base_cases, fresh_cases):
            for key in keys:
                if key in ref and got.get(key) != ref.get(key):
                    failures.append(
                        f"kernels: {section} {key} = {got.get(key)}, "
                        f"baseline {ref.get(key)}")
    # the depth variant must keep strictly undercutting the fused kernel
    for got in fresh.get("depth_vs_fused", []):
        d = got.get("gather_bytes_per_step_depth")
        f = got.get("gather_bytes_per_step_fused")
        if d is not None and f is not None and not d < f:
            failures.append(
                f"kernels: depth gather bytes/step {d} not strictly below "
                f"fused ({f})")
    # tuned selection may never lose to its conservative fallback — this
    # is the dispatch contract (kernels selected only where they win)
    base_sel = {r.get("key"): r for r in base.get("tuned_selection", [])}
    fresh_sel = fresh.get("tuned_selection", [])
    if base_sel and not fresh_sel:
        failures.append("kernels: fresh run recorded no tuned_selection")
    for got in fresh_sel:
        sp = got.get("selected_speedup")
        if sp is not None and float(sp) < 1.0:
            failures.append(
                f"kernels: tuned_selection {got.get('key')} selected "
                f"{got.get('selected')} at {float(sp):.2f}x vs fallback "
                f"{got.get('fallback')} (must be >= 1.0)")
        ref = base_sel.get(got.get("key"))
        if (ref is not None and fresh.get("platform") == base.get("platform")
                and got.get("selected") != ref.get("selected")):
            failures.append(
                f"kernels: tuned_selection {got.get('key')} selects "
                f"{got.get('selected')}, baseline {ref.get('selected')} "
                "(tuning drift — regenerate BENCH_kernels.json)")
    if "gate" not in fresh:
        failures.append("kernels: fresh run recorded no gate result")
    # wall-clock only where measured: interpret-mode timings (any
    # platform other than TPU) are not performance-representative
    if fresh.get("platform") == "tpu" and base.get("platform") == "tpu":
        for ref, got in zip(base.get("fused_vs_scan", []),
                            fresh.get("fused_vs_scan", [])):
            got_s, ref_s = got.get("speedup"), ref.get("speedup")
            if got_s is not None and ref_s is not None:
                if float(got_s) < float(ref_s) * WALL_CLOCK_FACTOR:
                    failures.append(
                        f"kernels: fused speedup {float(got_s):.2f}x below "
                        f"{WALL_CLOCK_FACTOR}x baseline ({float(ref_s):.2f}x)")


_CHECKS = (
    ("BENCH_nma.json", "nma", check_nma),
    ("BENCH_serve.json", "serve", check_serve),
    ("BENCH_kernels.json", "kernels", check_kernels),
)


def load_baselines(root: str = ".") -> dict:
    """Snapshot the committed baseline files into memory.  Call this
    BEFORE the bench run writes its own outputs — ``benchmarks.run``
    overwrites the same paths, and the gate must compare against what
    the repo promised, not what this run just produced."""
    return {fname: _load(os.path.join(root, fname))
            for fname, _, _ in _CHECKS}


def check_baselines(results: dict, baselines: Optional[dict] = None,
                    root: str = ".") -> list[str]:
    """Compare a ``benchmarks.run --smoke`` results dict against the
    committed baselines (preloaded via :func:`load_baselines`, or read
    from ``root``); returns failure messages (empty = gate passes).  A
    missing baseline file is a failure — the gate exists to be
    exercised, not silently skipped."""
    if baselines is None:
        baselines = load_baselines(root)
    failures: list[str] = []
    for fname, key, check in _CHECKS:
        base = baselines.get(fname)
        if base is None:
            failures.append(f"baseline {fname} not found under {root!r}")
            continue
        fresh = results.get(key)
        if fresh is None:
            failures.append(f"fresh run produced no {key!r} section")
            continue
        check(fresh, base, failures)
    return failures
