"""Execution-backend benchmarks and the CI kernel-path regression gate.

Three entry points, all wired through ``benchmarks/run.py``:

* :func:`run_nma` — per-order NMA via ``AnytimeRuntime.evaluate_orders``
  (one vmapped pass); the summary lands in ``BENCH_nma.json`` so NMA
  regressions across PRs show up in version control, not just curves.
* :func:`run_parity` — the smoke gate: the ``pallas`` (interpret) and
  ``sharded`` backends must reproduce the ``jnp-ref`` oracle's index
  state bit-for-bit under a mid-chunk advance pattern.  Raises on
  mismatch, so a kernel-path regression FAILS the build.
* :func:`run_stepplan_traces` — micro-benchmark of the acceptance
  criterion: step-plan bucketing caps distinct jit compilations for a
  squirrel order at ≤ 8 traces, vs one compilation per distinct
  dispatched run length on the legacy path.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_pipeline, runtime_for, timed
from repro.core.metrics import normalized_mean_accuracy
from repro.schedule import list_orders, rle_chunks


def run_nma(dataset: str = "magic", n_trees: int = 5, depth: int = 4,
            seed: int = 0, names=None, verbose: bool = True) -> dict:
    """Per-order NMA from one vmapped evaluate_orders pass."""
    fa, pp, yor, te, yte = build_pipeline(dataset, n_trees, depth, seed=seed,
                                          n_order=300, n_test=300)
    rt = runtime_for(fa, pp, yor)
    names = list(names) if names is not None else [
        n for n in list_orders()
        # qwyc orders assume binary labels; magic is binary so keep them,
        # but guard for other datasets
        if not (n.startswith("qwyc_") and int(yte.max()) > 1)
    ]
    curves, dt = timed(rt.evaluate_orders, te, yte, names)
    nma = {n: float(normalized_mean_accuracy(curves[n])) for n in names}
    if verbose:
        for n in sorted(nma, key=nma.get, reverse=True):
            print(f"nma,{dataset},{n},{nma[n]:.4f}")
        print(f"nma,{dataset},eval_s,{dt:.2f}")
    return {"dataset": dataset, "n_trees": n_trees, "depth": depth,
            "seed": seed, "nma": nma, "eval_s": dt}


def run_parity(dataset: str = "magic", n_trees: int = 4, depth: int = 5,
               n_test: int = 33, verbose: bool = True) -> dict:
    """Backend parity gate (raises AssertionError on divergence).

    Odd 33-sample batch + small kernel tiles force batch padding and
    multi-M-tile streaming; the advance pattern splits RLE runs
    mid-chunk.
    """
    fa, pp, yor, te, yte = build_pipeline(dataset, n_trees, depth,
                                          n_order=200, n_test=n_test)
    rt = runtime_for(fa, pp, yor)
    order = rt.order("backward_squirrel")
    opts = {"pallas": {"block_b": 16, "block_m": 8}, "sharded": {}}
    ref = rt.session(te, order=order, backend="jnp-ref")
    others = {n: rt.session(te, order=order, backend=n, **o)
              for n, o in opts.items()}
    timings = {}
    for k in (1, 2, 5, 1, 3, 10_000):
        ref.advance(k)
        for name, sess in others.items():
            _, dt = timed(sess.advance, k)
            timings.setdefault(name, 0.0)
            timings[name] += dt
            assert np.array_equal(
                np.asarray(sess.idx)[:n_test], np.asarray(ref.idx)
            ), f"{name} diverged from jnp-ref at pos {ref.pos}"
            np.testing.assert_allclose(
                sess.predict_proba(), ref.predict_proba(),
                rtol=1e-5, atol=1e-5,
                err_msg=f"{name} read-out diverged at pos {ref.pos}")
    if verbose:
        for name, dt in timings.items():
            print(f"backend_parity,{name},ok,advance_s,{dt:.3f}")
    return {"backends_checked": sorted(others), "steps": int(ref.pos),
            "advance_s": timings}


def run_stepplan_traces(dataset: str = "magic", n_trees: int = 6,
                        depth: int = 12, chunk: int = 10_000,
                        verbose: bool = True) -> dict:
    """Trace-count micro-benchmark (acceptance criterion).

    Replays a chunked deadline-style serving loop over a squirrel order
    and counts the distinct fused-segment lengths each strategy
    dispatches — on the legacy path every distinct length is a separate
    jit compilation of the scan; the step-plan buckets them to powers of
    two, bounded at 8.
    """
    fa, pp, yor, te, yte = build_pipeline(dataset, n_trees, depth,
                                          n_order=200, n_test=64)
    rt = runtime_for(fa, pp, yor)
    order = rt.order("backward_squirrel")

    # Legacy dispatch: one scan per RLE run, split only at chunk
    # boundaries — each distinct length is one jit trace.
    legacy_lengths: set[int] = set()
    pos = 0
    starts = np.concatenate(
        [[0], np.cumsum([n for _, n in rle_chunks(order)], dtype=np.int64)])
    while pos < len(order):
        budget = min(chunk, len(order) - pos)
        while budget:
            ci = int(np.searchsorted(starts, pos, side="right")) - 1
            step = min(budget, int(starts[ci + 1]) - pos)
            legacy_lengths.add(step)
            pos += step
            budget -= step

    sess = rt.session(te, order=order, backend="jnp-ref")
    while sess.remaining:
        sess.advance(chunk)
    plan_lengths = sess.backend.dispatched_lengths
    assert len(plan_lengths) <= 8, (
        f"step-plan dispatched {sorted(plan_lengths)} — more than 8 traces")
    if verbose:
        print(f"stepplan,traces_legacy,{len(legacy_lengths)},"
              f"lengths,{sorted(legacy_lengths)}")
        print(f"stepplan,traces_plan,{len(plan_lengths)},"
              f"lengths,{sorted(plan_lengths)}")
    return {"order": "backward_squirrel", "chunk": chunk,
            "n_trees": n_trees, "depth": depth,
            "legacy_traces": len(legacy_lengths),
            "plan_traces": len(plan_lengths),
            "plan_lengths": sorted(plan_lengths)}


def run(verbose: bool = True) -> dict:
    return {
        "parity": run_parity(verbose=verbose),
        "stepplan": run_stepplan_traces(verbose=verbose),
    }


if __name__ == "__main__":
    run()
    run_nma()
