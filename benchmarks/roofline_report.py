"""Render EXPERIMENTS.md roofline tables from dry-run JSON reports.

    PYTHONPATH=src python -m benchmarks.roofline_report reports/dryrun/*.json
"""
from __future__ import annotations

import glob
import json
import sys
from collections import OrderedDict


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load(paths):
    recs = []
    for p in paths:
        for g in glob.glob(p):
            recs.extend(json.load(open(g)))
    return recs


def render(recs, mesh_filter=None, require_unroll=None):
    seen = OrderedDict()
    for r in recs:
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        if require_unroll is not None and r.get("unroll", False) != require_unroll:
            continue
        key = (r["arch"], r["shape"], r.get("mesh"))
        seen[key] = r  # later files override earlier (re-runs)
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | dominant | MODEL/HLO flops | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in seen.items():
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | - | - | - | - | - | SKIP: {r.get('skipped','')[:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | - | - | - | - | - | FAIL |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {mesh} | {fmt_s(t['t_compute_s'])} | "
            f"{fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} | "
            f"**{t['dominant']}** | {t['useful_flops_ratio']:.2f} | ok |")
    return "\n".join(lines)


def main():
    paths = sys.argv[1:] or ["reports/dryrun/*.json"]
    recs = load(paths)
    print(render(recs))


if __name__ == "__main__":
    main()
