"""Paper Fig. 4: step-order generation runtime & mean accuracy vs #trees.

Claims under test (adult, depth 8, trees 2..N):
  * Optimal Order generation runtime grows exponentially and becomes
    infeasible quickly (the paper stops at 8 trees);
  * Backward Squirrel runtime stays polynomial (orders of magnitude
    lower) with comparable mean accuracy.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_pipeline, timed
from repro.core.metrics import mean_accuracy
from repro.core.anytime import AnytimeForest
from repro.schedule import get_order_policy


def run(depth: int = 8, max_trees: int = 8, optimal_limit: int = 6,
        dataset: str = "adult", verbose: bool = True):
    rows = []
    for t in range(2, max_trees + 1, 2):
        fa, pp, yor, te, yte = build_pipeline(dataset, t, depth, n_order=300)
        bwd_policy = get_order_policy("backward_squirrel")
        bwd, dt_b = timed(bwd_policy.generate, pp, yor)
        acc_b = mean_accuracy(AnytimeForest(fa, bwd).accuracy_curve(te, yte))
        row = {"trees": t, "squirrel_s": dt_b, "squirrel_mean_acc": acc_b}
        if t <= optimal_limit:
            opt_policy = get_order_policy("optimal")
            try:
                opt, dt_o = timed(opt_policy.generate, pp, yor)
                acc_o = mean_accuracy(AnytimeForest(fa, opt).accuracy_curve(te, yte))
                row.update({"optimal_s": dt_o, "optimal_mean_acc": acc_o,
                            "optimal_states": opt_policy.last_stats["states_evaluated"]})
            except (ValueError, MemoryError) as e:
                row["optimal_s"] = None
        rows.append(row)
        if verbose:
            o = row.get("optimal_s")
            print(f"fig4,trees={t},squirrel_s={dt_b:.3f},"
                  f"optimal_s={o if o is None else f'{o:.3f}'},"
                  f"acc_sq={acc_b:.4f},acc_opt={row.get('optimal_mean_acc', float('nan')):.4f}")
    # exponential vs polynomial check
    opt_times = [(r["trees"], r["optimal_s"]) for r in rows if r.get("optimal_s")]
    sq_times = [(r["trees"], r["squirrel_s"]) for r in rows]
    out = {"rows": rows}
    if len(opt_times) >= 2:
        growth_opt = opt_times[-1][1] / max(opt_times[0][1], 1e-9)
        growth_sq = sq_times[-1][1] / max(sq_times[0][1], 1e-9)
        out["optimal_growth"] = growth_opt
        out["squirrel_growth"] = growth_sq
        if verbose:
            print(f"fig4,growth,optimal={growth_opt:.1f}x,squirrel={growth_sq:.1f}x")
    return out


if __name__ == "__main__":
    run()
