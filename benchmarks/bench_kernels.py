"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this CPU container the numbers measure the *reference* path and the
interpret-mode kernel (functional, not performance-representative); on a
TPU the same harness times the compiled Mosaic kernels.  Derived column
reports achieved read throughput of the read-out kernel's gathers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, repeats=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []
    for B, T, M, C in [(1024, 10, 512, 10), (4096, 20, 2048, 26)]:
        idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
        probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
        t_ref = _time(jax.jit(ref.prob_accum_ref), idx, probs)
        gather_bytes = B * T * C * 4
        rows.append(("prob_accum_ref", B * T, t_ref * 1e6,
                     gather_bytes / t_ref / 1e9))
        if verbose:
            print(f"kernel,prob_accum_ref,B{B}xT{T}xM{M}xC{C},"
                  f"{t_ref*1e6:.1f}us,{gather_bytes/t_ref/1e9:.2f}GB/s")
    for B, F, M in [(1024, 16, 511), (4096, 54, 2047)]:
        idx1 = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
        X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        feature = jnp.asarray(rng.integers(0, F, size=M), jnp.int32)
        thr = jnp.asarray(rng.normal(size=M), jnp.float32)
        left = jnp.asarray(rng.integers(0, M, size=M), jnp.int32)
        right = jnp.asarray(rng.integers(0, M, size=M), jnp.int32)
        leaf = jnp.asarray(rng.random(M) < 0.3)
        t_ref = _time(jax.jit(ref.forest_step_ref), idx1, X, feature, thr,
                      left, right, leaf)
        rows.append(("forest_step_ref", B, t_ref * 1e6, B / t_ref / 1e6))
        if verbose:
            print(f"kernel,forest_step_ref,B{B}xF{F}xM{M},"
                  f"{t_ref*1e6:.1f}us,{B/t_ref/1e6:.2f}Msteps/s")
    return {"rows": rows}


if __name__ == "__main__":
    run()
