"""Kernel micro-benchmarks + the fused-kernel CI gate (BENCH_kernels.json).

Two comparisons, both written to ``BENCH_kernels.json`` by
``benchmarks/run.py`` for cross-PR regression tracking:

* **fused vs scanned** — the fused multi-step kernel
  (:func:`repro.kernels.ops.forest_run`: ONE launch per plan segment,
  node tables resident in VMEM) against the legacy path it replaced
  (:func:`~repro.kernels.ops.forest_run_scanned`: ``length`` launches
  of the single-step kernel under a scan);
* **slot kernel vs gather** — the masked-slot kernel
  (:func:`~repro.kernels.ops.slot_run`: per-slot tree ids on flattened
  VMEM-resident tables) against the generic per-slot jnp gather it
  replaced (:func:`~repro.kernels.ref.slot_run_ref`).

Gate semantics (``gate=True``, wired into ``run.py --smoke``): on a
real TPU the fused path must beat the scanned path by >=
``fused_gate_speedup`` x wall-clock or the build fails.  On CPU the
kernels execute in interpret mode, whose wall-clock is not
performance-representative — there the gate degrades to the
interpret-mode-safe assertion that both comparisons are BIT-IDENTICAL
(index state) / tolerance-identical (readout), raising on divergence so
a fused-kernel regression still fails the build.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, repeats=3, **kw):
    jax.block_until_ready(fn(*args, **kw))  # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / repeats


def _tree_tables(rng, M, F):
    return (
        jnp.asarray(rng.integers(0, F, size=M), jnp.int32),
        jnp.asarray(rng.normal(size=M), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.random(M) < 0.3),
    )


def run_fused_vs_scan(configs=None, verbose: bool = True) -> list[dict]:
    """Fused multi-step launch vs ``length`` scanned single-step
    launches; asserts bit-parity, reports wall-clock both ways."""
    rng = np.random.default_rng(0)
    rows = []
    for B, F, M, length in configs or [(128, 16, 127, 32), (256, 32, 255, 64)]:
        idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
        X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        tables = _tree_tables(rng, M, F)
        # time jitted callables on BOTH sides — the production executors
        # call these under jit, so per-call wrapper overhead
        # (pack_fields, budget check) must not pollute the gated ratio
        fused_j = jax.jit(lambda i, x, *t: ops.forest_run(
            i, x, *t, length=length))
        scan_j = jax.jit(lambda i, x, *t: ops.forest_run_scanned(
            i, x, *t, length=length))
        fused = fused_j(idx, X, *tables)
        scanned = scan_j(idx, X, *tables)
        assert np.array_equal(np.asarray(fused), np.asarray(scanned)), (
            f"fused forest_run diverged from the scanned path at "
            f"B{B} M{M} L{length}")
        t_fused = _time(fused_j, idx, X, *tables)
        t_scan = _time(scan_j, idx, X, *tables)
        row = {
            "B": B, "F": F, "M": M, "length": length,
            "launches_fused": 1, "launches_scanned": length,
            "fused_us": t_fused * 1e6, "scanned_us": t_scan * 1e6,
            "speedup": t_scan / t_fused,
        }
        rows.append(row)
        if verbose:
            print(f"kernel,fused_vs_scan,B{B}xM{M}xL{length},"
                  f"fused_us,{row['fused_us']:.0f},"
                  f"scanned_us,{row['scanned_us']:.0f},"
                  f"speedup,{row['speedup']:.2f}x")
    return rows


def run_slot_vs_gather(configs=None, verbose: bool = True) -> list[dict]:
    """Masked-slot kernel vs the generic per-slot gather path."""
    rng = np.random.default_rng(1)
    rows = []
    gather = jax.jit(ref.slot_run_ref, static_argnames=("length",))
    for S, T, M, F, length in configs or [(64, 8, 127, 16, 8),
                                          (128, 12, 255, 32, 16)]:
        idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
        X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
        tables = (
            jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32),
            jnp.asarray(rng.normal(size=(T, M)), jnp.float32),
            jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
            jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
            jnp.asarray(rng.random((T, M)) < 0.3),
        )
        units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
        mask = jnp.asarray(rng.random(S) < 0.8)
        kernel_j = jax.jit(lambda i, x, *a: ops.slot_run(
            i, x, *a, length=length))
        kernel = kernel_j(idx, X, *tables, units, mask)
        generic = gather(idx, X, *tables, units, mask, length=length)
        assert np.array_equal(np.asarray(kernel), np.asarray(generic)), (
            f"slot kernel diverged from the gather path at S{S} T{T} M{M}")
        t_kernel = _time(kernel_j, idx, X, *tables, units, mask)
        t_gather = _time(gather, idx, X, *tables, units, mask, length=length)
        row = {
            "S": S, "T": T, "M": M, "F": F, "length": length,
            "kernel_us": t_kernel * 1e6, "gather_us": t_gather * 1e6,
            "speedup": t_gather / t_kernel,
        }
        rows.append(row)
        if verbose:
            print(f"kernel,slot_vs_gather,S{S}xT{T}xM{M}xL{length},"
                  f"kernel_us,{row['kernel_us']:.0f},"
                  f"gather_us,{row['gather_us']:.0f},"
                  f"speedup,{row['speedup']:.2f}x")
    return rows


def run(verbose: bool = True, gate: bool = True,
        fused_gate_speedup: float = 1.5) -> dict:
    on_tpu = jax.default_backend() == "tpu"
    out = {
        "platform": jax.default_backend(),
        "fused_vs_scan": run_fused_vs_scan(verbose=verbose),
        "slot_vs_gather": run_slot_vs_gather(verbose=verbose),
    }
    if gate and on_tpu:
        worst = min(r["speedup"] for r in out["fused_vs_scan"])
        assert worst >= fused_gate_speedup, (
            f"fused forest_run only {worst:.2f}x the scanned path "
            f"(gate: >= {fused_gate_speedup}x)")
        out["gate"] = {"mode": "tpu-wallclock", "min_speedup": worst,
                       "threshold": fused_gate_speedup}
    elif gate:
        # interpret-mode wall-clock is not performance-representative;
        # the parity assertions above are the CPU gate (they raise —
        # and fail the build — on any fused-kernel divergence)
        out["gate"] = {"mode": "cpu-interpret-parity"}
        if verbose:
            print("kernel,gate,cpu-interpret-parity,ok")
    return out


if __name__ == "__main__":
    run()
