"""Kernel micro-benchmarks + the kernel CI gate (BENCH_kernels.json).

Four sections, all written to ``BENCH_kernels.json`` by
``benchmarks/run.py`` for cross-PR regression tracking:

* **fused vs scanned** — the fused multi-step kernel
  (:func:`repro.kernels.ops.forest_run` pinned to ``impl="fused"``)
  against the legacy path it replaced (``impl="scan"``);
* **slot kernel vs gather** — the flat masked-slot kernel
  (``impl="flat"``) against the generic per-slot jnp gather
  (``impl="gather"``);
* **depth vs fused** — the depth-aware gather-eliminated variant
  (:func:`repro.kernels.ops.forest_run_depth`, root-start) against the
  full-width fused kernel, including the analytical gather counters the
  variant exists to shrink;
* **tuned selection** — every registered implementation timed per
  shape, then the committed tuning record's pick re-measured against
  the best conservative fallback.  ``selected_speedup`` is EXACTLY 1.0
  when the record picks the fallback itself; the gate requires >= 1.0
  everywhere — a kernel is only ever selected where it wins.

Every row also records the platform-independent analytical counters
(``tools.perf.counters``: launches, gather rows/bytes per step,
resident bytes), which is what the CPU gate and the baseline check
compare — interpret-mode wall-clock is not performance-representative,
so on CPU the gate asserts bit-parity between all impls plus the
counter invariants (depth strictly below full width, tuned selection
never slower than its fallback) instead of raw timings.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import layout as klayout
from repro.kernels import ops, ref, tuning
from tools.perf import counters as perfc

#: shapes mirror tools.perf.report.SOLO_SHAPES / SLOT_SHAPES
SOLO_CONFIGS = [(128, 16, 127, 32), (256, 32, 255, 64)]
SLOT_CONFIGS = [(64, 8, 127, 16, 8), (128, 12, 255, 32, 16)]

_SOLO_FALLBACK = "scan"
_SLOT_FALLBACK = "gather"


def _time(fn, *args, repeats=3, **kw):
    jax.block_until_ready(fn(*args, **kw))  # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / repeats


def _tree_tables(rng, M, F):
    return (
        jnp.asarray(rng.integers(0, F, size=M), jnp.int32),
        jnp.asarray(rng.normal(size=M), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.random(M) < 0.3),
    )


def _structured_tree(rng, M, F):
    """A real binary tree (heap topology) under a random node-label
    permutation fixing the root — the shape the depth-aware layout has
    to actually reorder, unlike the uniform-random tables above."""
    perm = np.concatenate([[0], 1 + rng.permutation(M - 1)])
    left = np.zeros(M, np.int64)
    right = np.zeros(M, np.int64)
    is_leaf = np.zeros(M, bool)
    for i in range(M):
        lo, hi = 2 * i + 1, 2 * i + 2
        if hi < M:
            left[i], right[i] = perm[lo], perm[hi]
        else:
            is_leaf[i] = True
            left[i] = right[i] = perm[i]
    inv = np.empty(M, np.int64)
    inv[perm] = np.arange(M)
    return (
        jnp.asarray(rng.integers(0, F, size=M), jnp.int32),
        jnp.asarray(rng.normal(size=M), jnp.float32),
        jnp.asarray(left[inv], jnp.int32),
        jnp.asarray(right[inv], jnp.int32),
        jnp.asarray(is_leaf[inv]),
    )


def run_fused_vs_scan(configs=None, verbose: bool = True) -> list[dict]:
    """Fused multi-step launch vs ``length`` scanned single-step
    launches; asserts bit-parity, reports wall-clock + counters."""
    rng = np.random.default_rng(0)
    rows = []
    for B, F, M, length in configs or SOLO_CONFIGS:
        idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
        X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        tables = _tree_tables(rng, M, F)
        # time jitted callables on BOTH sides — the production executors
        # call these under jit, so per-call wrapper overhead
        # (pack_fields, budget check) must not pollute the gated ratio
        fused_j = jax.jit(lambda i, x, *t: ops.forest_run(
            i, x, *t, length=length, impl="fused"))
        scan_j = jax.jit(lambda i, x, *t: ops.forest_run(
            i, x, *t, length=length, impl="scan"))
        fused = fused_j(idx, X, *tables)
        scanned = scan_j(idx, X, *tables)
        assert np.array_equal(np.asarray(fused), np.asarray(scanned)), (
            f"fused forest_run diverged from the scanned path at "
            f"B{B} M{M} L{length}")
        t_fused = _time(fused_j, idx, X, *tables)
        t_scan = _time(scan_j, idx, X, *tables)
        c_fused = perfc.solo_counters("fused", M=M, length=length)
        c_scan = perfc.solo_counters("scan", M=M, length=length)
        row = {
            "B": B, "F": F, "M": M, "length": length,
            "launches_fused": c_fused["launches"],
            "launches_scanned": c_scan["launches"],
            "gather_bytes_per_step": c_fused["gather_bytes_per_step"],
            "resident_bytes": c_fused["resident_bytes"],
            "fused_us": t_fused * 1e6, "scanned_us": t_scan * 1e6,
            "speedup": t_scan / t_fused,
        }
        rows.append(row)
        if verbose:
            print(f"kernel,fused_vs_scan,B{B}xM{M}xL{length},"
                  f"fused_us,{row['fused_us']:.0f},"
                  f"scanned_us,{row['scanned_us']:.0f},"
                  f"speedup,{row['speedup']:.2f}x")
    return rows


def run_slot_vs_gather(configs=None, verbose: bool = True) -> list[dict]:
    """Flat masked-slot kernel vs the generic per-slot gather path."""
    rng = np.random.default_rng(1)
    rows = []
    gather = jax.jit(ref.slot_run_ref, static_argnames=("length",))
    for S, T, M, F, length in configs or SLOT_CONFIGS:
        idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
        X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
        tables = (
            jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32),
            jnp.asarray(rng.normal(size=(T, M)), jnp.float32),
            jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
            jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
            jnp.asarray(rng.random((T, M)) < 0.3),
        )
        units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
        mask = jnp.asarray(rng.random(S) < 0.8)
        kernel_j = jax.jit(lambda i, x, *a: ops.slot_run(
            i, x, *a, length=length, impl="flat"))
        kernel = kernel_j(idx, X, *tables, units, mask)
        generic = gather(idx, X, *tables, units, mask, length=length)
        assert np.array_equal(np.asarray(kernel), np.asarray(generic)), (
            f"slot kernel diverged from the gather path at S{S} T{T} M{M}")
        t_kernel = _time(kernel_j, idx, X, *tables, units, mask)
        t_gather = _time(gather, idx, X, *tables, units, mask, length=length)
        c_flat = perfc.slot_counters("flat", T=T, M=M, length=length)
        row = {
            "S": S, "T": T, "M": M, "F": F, "length": length,
            "launches_kernel": c_flat["launches"],
            "gather_bytes_per_step": c_flat["gather_bytes_per_step"],
            "resident_bytes": c_flat["resident_bytes"],
            "kernel_us": t_kernel * 1e6, "gather_us": t_gather * 1e6,
            "speedup": t_gather / t_kernel,
        }
        rows.append(row)
        if verbose:
            print(f"kernel,slot_vs_gather,S{S}xT{T}xM{M}xL{length},"
                  f"kernel_us,{row['kernel_us']:.0f},"
                  f"gather_us,{row['gather_us']:.0f},"
                  f"speedup,{row['speedup']:.2f}x")
    return rows


def run_depth_vs_fused(configs=None, verbose: bool = True) -> list[dict]:
    """Depth-aware gather-eliminated run (fresh, root-start) vs the
    full-width fused kernel: bit-parity, wall-clock, and the analytical
    gather counters — the depth variant's gather bytes/step must be
    STRICTLY below the fused kernel's (the row the CI counter gate
    pins)."""
    rng = np.random.default_rng(2)
    rows = []
    for B, F, M, length in configs or SOLO_CONFIGS:
        X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        tables = _structured_tree(rng, M, F)
        lay = klayout.build_depth_layout(*tables)
        idx0 = jnp.zeros(B, jnp.int32)  # root start: the fresh shape
        fused_j = jax.jit(lambda i, x, *t: ops.forest_run(
            i, x, *t, length=length, impl="fused"))
        depth_j = jax.jit(lambda i, x: ops.forest_run_depth(
            i, x, lay, 0, length=length, start_step=0))
        fused = fused_j(idx0, X, *tables)
        depth = depth_j(idx0, X)
        assert np.array_equal(np.asarray(depth), np.asarray(fused)), (
            f"depth-aware forest_run diverged from fused at B{B} M{M} "
            f"L{length}")
        # real layout widths must stay within the analytical model
        widths = lay.step_widths(0, length)
        model = perfc.depth_step_widths(length, lay.Mp, levels=None)
        assert all(w <= m for w, m in zip(widths, model)), (
            f"layout widths {widths} exceed the counter model {model}")
        t_fused = _time(fused_j, idx0, X, *tables)
        t_depth = _time(depth_j, idx0, X)
        c_fused = perfc.solo_counters("fused", M=M, length=length)
        c_depth = perfc.solo_counters("depth", M=M, length=length)
        row = {
            "B": B, "F": F, "M": M, "length": length,
            "unrolled_widths": [int(w) for w in widths],
            "gather_bytes_per_step_depth": c_depth["gather_bytes_per_step"],
            "gather_bytes_per_step_fused": c_fused["gather_bytes_per_step"],
            "fused_us": t_fused * 1e6, "depth_us": t_depth * 1e6,
            "speedup": t_fused / t_depth,
        }
        rows.append(row)
        if verbose:
            print(f"kernel,depth_vs_fused,B{B}xM{M}xL{length},"
                  f"depth_us,{row['depth_us']:.0f},"
                  f"fused_us,{row['fused_us']:.0f},"
                  f"gather_bytes,{row['gather_bytes_per_step_depth']:g}"
                  f"/{row['gather_bytes_per_step_fused']:g}")
    return rows


def run_tuned_selection(verbose: bool = True) -> list[dict]:
    """Re-measure every registered impl per shape and audit the
    committed tuning record's pick against the best conservative
    fallback.

    All impls are asserted BIT-IDENTICAL first (selection may only ever
    change which one runs).  ``selected_speedup`` is the gated number:
    exactly 1.0 when the record picks the fallback, else
    ``fallback_us / selected_us`` — >= 1.0 means the kernel the record
    selected actually wins on this platform, here, now.
    """
    rng = np.random.default_rng(3)
    rows = []
    for B, F, M, length in SOLO_CONFIGS:
        idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
        X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        tables = _tree_tables(rng, M, F)
        timings, outs = {}, {}
        for name in sorted(tuning.SOLO_IMPLS):
            fn = jax.jit(lambda i, x, *t, _n=name: ops.forest_run(
                i, x, *t, length=length, impl=_n))
            outs[name] = np.asarray(fn(idx, X, *tables))
            timings[name] = _time(fn, idx, X, *tables) * 1e6
        base = outs[_SOLO_FALLBACK]
        for name, out in outs.items():
            assert np.array_equal(out, base), (
                f"solo impl {name} diverged at M{M} L{length}")
        key = tuning.solo_key(perfc.pad_m(M), length)
        selected, _ = tuning.select("solo", key)
        speedup = (1.0 if selected == _SOLO_FALLBACK
                   else timings[_SOLO_FALLBACK] / timings[selected])
        rows.append({
            "path": "solo", "key": key, "selected": selected,
            "fallback": _SOLO_FALLBACK,
            "timings_us": {k: round(v, 1) for k, v in timings.items()},
            "selected_speedup": speedup,
        })
        if verbose:
            print(f"kernel,tuned_selection,solo,{key},selected,{selected},"
                  f"speedup,{speedup:.2f}x")
    for S, T, M, F, length in SLOT_CONFIGS:
        idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
        X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
        tables = (
            jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32),
            jnp.asarray(rng.normal(size=(T, M)), jnp.float32),
            jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
            jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
            jnp.asarray(rng.random((T, M)) < 0.3),
        )
        units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
        mask = jnp.asarray(rng.random(S) < 0.8)
        timings, outs = {}, {}
        for name in sorted(tuning.SLOT_IMPLS):
            fn = jax.jit(lambda i, x, u, m, *t, _n=name: ops.slot_run(
                i, x, *t, u, m, length=length, impl=_n))
            outs[name] = np.asarray(fn(idx, X, units, mask, *tables))
            timings[name] = _time(fn, idx, X, units, mask, *tables) * 1e6
        base = outs[_SLOT_FALLBACK]
        for name, out in outs.items():
            assert np.array_equal(out, base), (
                f"slot impl {name} diverged at T{T} M{M} L{length}")
        key = tuning.slot_key(T, perfc.pad_m(M), length)
        selected, _ = tuning.select("slot", key)
        speedup = (1.0 if selected == _SLOT_FALLBACK
                   else timings[_SLOT_FALLBACK] / timings[selected])
        rows.append({
            "path": "slot", "key": key, "selected": selected,
            "fallback": _SLOT_FALLBACK,
            "timings_us": {k: round(v, 1) for k, v in timings.items()},
            "selected_speedup": speedup,
        })
        if verbose:
            print(f"kernel,tuned_selection,slot,{key},selected,{selected},"
                  f"speedup,{speedup:.2f}x")
    return rows


def run(verbose: bool = True, gate: bool = True,
        fused_gate_speedup: float = 1.5) -> dict:
    on_tpu = jax.default_backend() == "tpu"
    out = {
        "platform": jax.default_backend(),
        "fused_vs_scan": run_fused_vs_scan(verbose=verbose),
        "slot_vs_gather": run_slot_vs_gather(verbose=verbose),
        "depth_vs_fused": run_depth_vs_fused(verbose=verbose),
        "tuned_selection": run_tuned_selection(verbose=verbose),
    }
    if gate:
        # counter invariants hold on EVERY platform (analytical, not
        # wall-clock): depth strictly undercuts full width, and the
        # tuning record never selects an impl that loses to its fallback
        for row in out["depth_vs_fused"]:
            assert (row["gather_bytes_per_step_depth"]
                    < row["gather_bytes_per_step_fused"]), (
                f"depth variant gather bytes/step "
                f"{row['gather_bytes_per_step_depth']} not below fused "
                f"{row['gather_bytes_per_step_fused']}")
        worst_sel = min(r["selected_speedup"] for r in out["tuned_selection"])
        assert worst_sel >= 1.0, (
            f"tuned selection regresses vs its fallback "
            f"({worst_sel:.2f}x; the record must only select winners)")
    if gate and on_tpu:
        worst = min(r["speedup"] for r in out["fused_vs_scan"])
        assert worst >= fused_gate_speedup, (
            f"fused forest_run only {worst:.2f}x the scanned path "
            f"(gate: >= {fused_gate_speedup}x)")
        out["gate"] = {"mode": "tpu-wallclock", "min_speedup": worst,
                       "min_selected_speedup": worst_sel,
                       "threshold": fused_gate_speedup}
    elif gate:
        # interpret-mode wall-clock is not performance-representative;
        # the parity assertions + analytical counter invariants above
        # are the CPU gate (they raise — and fail the build — on any
        # kernel divergence or counter regression)
        out["gate"] = {"mode": "cpu-interpret-counters",
                       "min_selected_speedup": worst_sel}
        if verbose:
            print("kernel,gate,cpu-interpret-counters,ok")
    return out


if __name__ == "__main__":
    run()
