"""Paper Fig. 6: normalized mean accuracy across datasets x orders.

Reproduces the headline numbers:
  * Optimal achieves ~97% of the best NMA (where feasible);
  * Backward Squirrel ~94% of the best NMA with Optimal present and
    ~99% of the best without it;
  * depth variants beat breadth on non-binary datasets, reversed for
    binary datasets.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_pipeline, curve_for
from repro.core.metrics import normalized_mean_accuracy
from repro.forest.data import DATASETS

SMALL_ORDERS = ("optimal", "unoptimal", "backward_squirrel", "forward_squirrel",
                "random", "depth", "breadth",
                "prune_depth_IE", "prune_breadth_IE",
                "prune_depth_EA", "prune_breadth_EA",
                "prune_depth_RE", "prune_breadth_RE",
                "prune_depth_D", "prune_breadth_D")
LARGE_ORDERS = tuple(n for n in SMALL_ORDERS if n not in ("optimal", "unoptimal"))


def _qwyc_names(dataset):
    return ("qwyc_depth", "qwyc_breadth") if DATASETS[dataset].binary else ()


def run(datasets=None, small=(5, 4), large=(10, 8), seeds=(0, 1),
        verbose: bool = True):
    datasets = datasets or list(DATASETS)
    table: dict[str, dict[str, float]] = {}
    for ds in datasets:
        accum: dict[str, list[float]] = {}
        for seed in seeds:
            # small grid: with Optimal
            fa, pp, yor, te, yte = build_pipeline(ds, *small, seed=seed,
                                                  n_order=400, n_test=400)
            for name in SMALL_ORDERS + _qwyc_names(ds):
                c = curve_for(fa, pp, yor, te, yte, name, seed=seed)
                accum.setdefault(name + "@small", []).append(
                    normalized_mean_accuracy(c))
            # large grid: without Optimal
            fa, pp, yor, te, yte = build_pipeline(ds, *large, seed=seed,
                                                  n_order=400, n_test=400)
            for name in LARGE_ORDERS + _qwyc_names(ds):
                c = curve_for(fa, pp, yor, te, yte, name, seed=seed)
                accum.setdefault(name + "@large", []).append(
                    normalized_mean_accuracy(c))
        table[ds] = {k: float(np.mean(v)) for k, v in accum.items()}
        if verbose:
            s = table[ds]
            print(f"fig6,{ds},opt={s.get('optimal@small', float('nan')):.4f},"
                  f"bwd_sq={s['backward_squirrel@small']:.4f},"
                  f"depth={s['depth@small']:.4f},breadth={s['breadth@small']:.4f},"
                  f"unopt={s.get('unoptimal@small', float('nan')):.4f}")

    # headline ratios ------------------------------------------------------
    def ratios(suffix, names):
        out = []
        for ds in datasets:
            s = {k[: -len(suffix) - 1]: v for k, v in table[ds].items()
                 if k.endswith("@" + suffix)}
            if not s:
                continue
            best = max(s.values())
            out.append({n: s[n] / best for n in names if n in s})
        return {n: float(np.mean([r[n] for r in out if n in r]))
                for n in names}

    small_r = ratios("small", ("optimal", "backward_squirrel", "forward_squirrel"))
    large_r = ratios("large", ("backward_squirrel", "forward_squirrel", "depth"))
    summary = {
        "optimal_vs_best_small": small_r.get("optimal"),
        "bwd_squirrel_vs_best_small": small_r.get("backward_squirrel"),
        "bwd_squirrel_vs_best_large": large_r.get("backward_squirrel"),
    }
    # binary vs non-binary depth/breadth flip
    for kind, names in (("binary", [d for d in datasets if DATASETS[d].binary]),
                        ("multi", [d for d in datasets if not DATASETS[d].binary])):
        if names:
            d_minus_b = np.mean([
                table[d]["depth@small"] - table[d]["breadth@small"] for d in names])
            summary[f"depth_minus_breadth_{kind}"] = float(d_minus_b)
    if verbose:
        for k, v in summary.items():
            print(f"fig6,summary,{k},{v if v is None else f'{v:.4f}'}")
    return {"table": table, "summary": summary}


if __name__ == "__main__":
    run()
