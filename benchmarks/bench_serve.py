"""Serving benchmark + CI gate: batched deadline scheduling vs the
serial per-request session loop it replaces, the threaded driver vs the
cooperative loop, and the degrade-vs-reject admission frontier.

Workloads over the same forest, order, and request stream:

* **complete** — generous deadlines, every request runs its full step
  order; isolates pure throughput (requests/sec).  Measured three ways:
  the serial per-session baseline, the cooperative batched loop
  (caller pumps ``drain()``), and the THREADED loop (background
  ``ServeDriver`` owns dispatch→admit→harvest; the caller only submits
  and blocks on tickets).  Both batched modes are gated at
  >= ``min_speedup`` x serial with >= ``min_hit_rate`` hit-rate.
* **tight** — millisecond deadlines; reports the anytime quality
  profile under pressure (deadline-hit-rate, p50/p99
  steps-at-deadline, slot occupancy).
* **overload** — many more requests than slots, once under
  ``admission="reject"`` and once under ``admission="degrade"``, at an
  SLA generous enough that admitted work can be served (so the
  admission policy, not the machine's speed, decides who answers).
  Hit-rate counts REJECTED submissions as misses (the caller's view of
  the offered load), so this measures the frontier the degrade policy
  exists for: shrink per-request budgets smoothly instead of shedding —
  degrade's ``steps_p50`` shows the budget price paid for its
  hit-rate.  Gated: degrade must dominate reject on hit-rate at equal
  load.
* **guaranteed** — the certified contract end-to-end on every backend
  (jnp-ref, pallas, sharded): calibrate a fresh WCET cost model on
  THIS machine, submit a slot-filling wave of ``guaranteed=True``
  requests at a deadline derived from the priced worst case, and hold
  the contract as a hard gate — zero deadline misses, every delivery
  bit-identical to a solo jnp-ref session run to completion, and a
  provably-infeasible deadline refused at submit with the priced bound
  in the error.

The serial baseline is the pre-``repro.serve`` deployment shape: one
fresh :class:`~repro.schedule.runtime.Session` per request, advanced
under its own deadline.  Each solo session closes over its own input
row, so every request re-traces its fused-segment dispatches — exactly
the per-request overhead the slot-batched scheduler amortizes across
``capacity`` concurrent requests (shared StepPlan, shared jit traces,
one masked dispatch for everyone).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_pipeline, runtime_for
from benchmarks.loadgen import calibrate_cost_model
from repro.obs import Tracer, write_chrome_trace
from repro.serve import (
    AdmissionRejected,
    AnytimeServer,
    CertificationFailed,
    QoS,
)


def _serial_loop(rt, order, rows, deadline_ms):
    """The pre-serve baseline: one session per request, own deadline."""
    steps = []
    t0 = time.perf_counter()
    for row in rows:
        sess = rt.session(row[None, :], order=order, backend="jnp-ref")
        sess.advance_until(deadline_ms)
        np.asarray(sess.predict_proba())  # deliver the anytime readout
        steps.append(sess.pos)
    dt = time.perf_counter() - t0
    steps = np.asarray(steps)
    return {
        "requests": len(rows),
        "wall_s": dt,
        "requests_per_sec": len(rows) / dt,
        "deadline_hit_rate": float((steps > 0).mean()),
        "steps_p50": float(np.percentile(steps, 50)),
        "steps_p99": float(np.percentile(steps, 99)),
    }


def _result_stats(results, dt, snap):
    steps = np.asarray([r.steps_completed for r in results])
    return {
        "requests": len(results),
        "wall_s": dt,
        "requests_per_sec": len(results) / dt,
        "deadline_hit_rate": float(np.mean([r.deadline_hit for r in results])),
        "steps_p50": float(np.percentile(steps, 50)),
        "steps_p99": float(np.percentile(steps, 99)),
        "slot_occupancy": snap["slot_occupancy"],
        "dispatches": snap["dispatches"],
    }


def _batched_loop(rt, rows, deadline_ms, capacity, warmup: bool = False,
                  tracer=None):
    """Cooperative mode: the caller pumps the loop via ``serve()``."""
    server = AnytimeServer(rt, capacity=capacity, tracer=tracer)
    if warmup:
        # compile the slot batch's fused-segment traces before timing —
        # millisecond deadlines are meaningless against cold jit compiles
        server.serve(list(rows[:capacity]), deadline_ms=300_000.0)
        server.metrics.reset()
    t0 = time.perf_counter()
    results = server.serve(list(rows), deadline_ms=deadline_ms)
    dt = time.perf_counter() - t0
    assert len(results) == len(rows)
    return _result_stats(results, dt, server.metrics.snapshot())


def _threaded_loop(rt, rows, deadline_ms, capacity, warmup: bool = False):
    """Threaded mode: the background driver owns the loop; the caller
    fire-and-forgets submissions and blocks on tickets."""
    with AnytimeServer(rt, capacity=capacity) as server:
        if warmup:
            warm_qos = QoS(deadline_ms=300_000.0)
            for t in [server.submit(x, warm_qos) for x in rows[:capacity]]:
                t.result(timeout=600.0)
            server.metrics.reset()
        qos = QoS(deadline_ms=deadline_ms)
        t0 = time.perf_counter()
        tickets = [server.submit(x, qos) for x in rows]
        results = [t.result(timeout=600.0) for t in tickets]
        dt = time.perf_counter() - t0
        snap = server.metrics.snapshot()
    return _result_stats(results, dt, snap)


def _overload_loop(rt, rows, deadline_ms, capacity, n_requests,
                   admission, admission_k):
    """Offered-load frontier: submit ``n_requests`` >> capacity under a
    tight deadline; hit-rate counts rejected submissions as misses."""
    server = AnytimeServer(rt, capacity=capacity,
                           admission=admission, admission_k=admission_k)
    server.serve(list(rows[:capacity]), deadline_ms=300_000.0)  # warm traces
    server.metrics.reset()
    qos = QoS(deadline_ms=deadline_ms)
    tickets, rejected = [], 0
    t0 = time.perf_counter()
    for i in range(n_requests):
        try:
            tickets.append(server.submit(rows[i % len(rows)], qos))
        except AdmissionRejected:
            rejected += 1
    server.drain()
    dt = time.perf_counter() - t0
    results = [t.result() for t in tickets]
    hits = sum(r.deadline_hit for r in results)
    steps = np.asarray([r.steps_completed for r in results])
    budgets = np.asarray([r.budget_steps for r in results])
    return {
        "admission": admission,
        "requests_offered": n_requests,
        "admitted": len(results),
        "rejected": rejected,
        "wall_s": dt,
        # the caller's view of the offered load: a rejection is a miss
        "hit_rate": hits / n_requests,
        "served_hit_rate": hits / len(results) if results else 0.0,
        "degraded_requests": sum(r.degraded for r in results),
        "steps_p50": float(np.percentile(steps, 50)) if steps.size else 0.0,
        "steps_p99": float(np.percentile(steps, 99)) if steps.size else 0.0,
        "budget_p50": float(np.percentile(budgets, 50)) if budgets.size else 0.0,
    }


#: every backend the guaranteed=True contract is held on
_GUARANTEED_BACKENDS = ("jnp-ref", "pallas", "sharded")


def _guaranteed_wave(rt, rows, capacity, backend, ref_proba,
                     margin: float = 3.0, slack: float = 6.0):
    """One backend's certified wave.

    Calibrates a fresh :class:`~repro.serve.CostModel` on this machine
    (a certificate priced from another machine's maxima proves nothing
    here), warms the certified server's own jit traces AND every
    admission-flush width (certification prices steady state, so
    nothing cold may land inside a timed deadline), then submits a
    slot-filling wave of ``guaranteed=True`` requests at ``slack`` x
    the priced full-plan worst case.  Returns the contract evidence:
    completions, deadline misses (ticket-observed and metrics-counted),
    bit-parity vs the solo jnp-ref oracle, and whether a provably
    infeasible deadline was refused at submit with the priced bound in
    the error message.
    """
    cost_model, total = calibrate_cost_model(
        rt, rows, capacity=capacity, backend=backend, margin=margin)
    server = AnytimeServer(rt, capacity=capacity, cost_model=cost_model)
    server.serve(list(rows[:capacity]), deadline_ms=300_000.0,
                 backend=backend)
    for k in range(1, capacity + 1):
        for j in range(k):
            server.submit(rows[j % len(rows)], QoS(
                deadline_ms=300_000.0, backend=backend, budget_steps=1))
        server.drain()
    server.metrics.reset()

    wcet_full = cost_model.request_wcet_ms(total, backend=backend)
    deadline_ms = slack * wcet_full
    qos = QoS(deadline_ms=deadline_ms, backend=backend, guaranteed=True)
    t0 = time.perf_counter()
    tickets = [server.submit(row, qos) for row in rows[:capacity]]
    server.drain()
    dt = time.perf_counter() - t0
    results = [t.result() for t in tickets]
    misses = sum(1 for r in results
                 if not r.completed or r.latency_ms > deadline_ms)
    if backend == "pallas":
        # prob_accum associates float sums differently; readout parity
        # to kernel tolerance (same contract as tests/test_serve.py)
        parity = all(np.allclose(np.asarray(r.proba), ref,
                                 rtol=1e-5, atol=1e-5)
                     for r, ref in zip(results, ref_proba))
    else:
        parity = all(np.array_equal(np.asarray(r.proba), ref)
                     for r, ref in zip(results, ref_proba))
    # the rejection side of the contract: a deadline the priced worst
    # case provably cannot meet must be refused at submit, bound in hand
    rejected_infeasible, priced_in_error = 0, False
    try:
        server.submit(rows[0], QoS(deadline_ms=0.001, backend=backend,
                                   guaranteed=True))
    except CertificationFailed as e:
        rejected_infeasible = 1
        priced_in_error = (e.wcet_ms is not None
                           and f"{e.wcet_ms:.3f}" in str(e))
    snap = server.metrics.snapshot()
    return {
        "backend": backend,
        "requests": len(results),
        "wall_s": dt,
        "deadline_ms": deadline_ms,
        "priced_full_wcet_ms": wcet_full,
        "completed": sum(r.completed for r in results),
        "misses": misses,
        "metrics_misses": snap["guaranteed_misses"],
        "certified_admitted": snap["certified_admitted"],
        "certified_rejected": snap["certified_rejected"],
        "parity_vs_solo": bool(parity),
        "rejected_infeasible": rejected_infeasible,
        "priced_bound_in_error": priced_in_error,
    }


def _guaranteed_loops(rt, order, rows, capacity):
    """The certified contract on every backend, against one shared
    solo-session oracle (jnp-ref, full plan — what a completed
    guaranteed delivery must be bit-identical to)."""
    ref_proba = []
    for row in rows[:capacity]:
        sess = rt.session(row[None, :], order=order, backend="jnp-ref")
        sess.advance_until(300_000.0)
        # [0]: a delivered Result carries the per-request row, not the
        # solo session's singleton batch axis
        ref_proba.append(np.asarray(sess.predict_proba())[0])
    backends = {b: _guaranteed_wave(rt, rows, capacity, b, ref_proba)
                for b in _GUARANTEED_BACKENDS}
    return {
        "backends": backends,
        "misses": sum(b["misses"] for b in backends.values()),
        "metrics_misses":
            sum(b["metrics_misses"] for b in backends.values()),
        "rejected_infeasible":
            sum(b["rejected_infeasible"] for b in backends.values()),
    }


def _obs_loops(rt, rows, capacity):
    """Tracing cost and completeness, all runs warmed (compiles would
    swamp the percent-level overhead being measured):

    * **off** — server holds a *disabled* ``Tracer``: instrumentation
      sites take the compiled-out fast path (one attribute read).  Gated
      to stay within noise of the untraced server (``NULL_TRACER``).
    * **on** — full tracing with margin telemetry; the exported trace is
      the record→export→schema-validate round-trip artifact CI feeds to
      ``python -m tools.obs --check``.
    """
    generous = 300_000.0
    untraced = _batched_loop(rt, rows, generous, capacity, warmup=True)
    off = _batched_loop(rt, rows, generous, capacity, warmup=True,
                        tracer=Tracer(enabled=False))
    traced = Tracer(margins=True)
    on = _batched_loop(rt, rows, generous, capacity, warmup=True,
                       tracer=traced)
    return untraced, off, on, traced


def run(dataset: str = "magic", n_trees: int = 10, depth: int = 6,
        capacity: int = 16, n_requests: int = 48,
        tight_deadline_ms: float = 30.0, overload_deadline_ms: float = 5_000.0,
        seed: int = 0, min_speedup: float = 3.0, min_hit_rate: float = 0.99,
        min_trace_off_ratio: float = 0.6,
        trace_path: str = "reports/obs/serve_trace_smoke.json",
        gate: bool = True, verbose: bool = True) -> dict:
    """Serving comparison; raises (failing the smoke build) when the
    gated thresholds are missed."""
    fa, pp, yor, te, yte = build_pipeline(
        dataset, n_trees, depth, seed=seed, n_order=200,
        n_test=max(n_requests, 64))
    rt = runtime_for(fa, pp, yor)
    order = rt.order("backward_squirrel")
    rows = te[:n_requests]
    generous = 300_000.0  # every request completes: pure throughput

    out = {"dataset": dataset, "n_trees": n_trees, "depth": depth,
           "capacity": capacity, "n_requests": n_requests,
           "total_steps": int(len(order))}
    out["serial"] = _serial_loop(rt, order, rows, generous)
    out["batched"] = _batched_loop(rt, rows, generous, capacity)
    out["threaded"] = _threaded_loop(rt, rows, generous, capacity)
    serial_rps = out["serial"]["requests_per_sec"]
    out["speedup"] = out["batched"]["requests_per_sec"] / serial_rps
    out["threaded_speedup"] = out["threaded"]["requests_per_sec"] / serial_rps
    # tight workload sized to capacity: the anytime-quality profile of
    # one in-flight generation (oversubscribed tight workloads measure
    # admission-control behavior instead — the overload section below)
    out["tight"] = {
        "deadline_ms": tight_deadline_ms,
        "serial": _serial_loop(rt, order, rows[:capacity], tight_deadline_ms),
        "batched": _batched_loop(rt, rows[:capacity], tight_deadline_ms,
                                 capacity, warmup=True),
    }
    # observability: disabled-tracer overhead gate + traced export
    untraced, off, on, traced = _obs_loops(rt, rows, capacity)
    attrs = list(traced.attributions)
    out["obs"] = {
        "untraced_rps": untraced["requests_per_sec"],
        "disabled_rps": off["requests_per_sec"],
        "traced_rps": on["requests_per_sec"],
        "disabled_ratio":
            off["requests_per_sec"] / untraced["requests_per_sec"],
        "attributions": len(attrs),
        "attribution_sum_fail": sum(1 for a in attrs if not a.check()),
        "events": len(traced.events()),
        "dropped": traced.dropped,
    }
    if trace_path:
        doc = write_chrome_trace(traced, trace_path, meta={
            "bench": "bench_serve", "dataset": dataset,
            "capacity": capacity, "n_requests": len(rows)})
        out["obs"]["trace_path"] = trace_path
        out["obs"]["trace_events"] = len(doc["traceEvents"])
    # certified serving: the guaranteed=True contract on every backend
    out["guaranteed"] = _guaranteed_loops(rt, order, rows, capacity)
    # overload frontier: reject sheds at submit, degrade shrinks budgets
    overload_n = 6 * capacity
    out["overload"] = {
        "deadline_ms": overload_deadline_ms,
        "requests_offered": overload_n,
        "admission_k": 1.0,
        "reject": _overload_loop(rt, rows, overload_deadline_ms, capacity,
                                 overload_n, "reject", 1.0),
        "degrade": _overload_loop(rt, rows, overload_deadline_ms, capacity,
                                  overload_n, "degrade", 1.0),
    }

    if verbose:
        for name in ("serial", "batched", "threaded"):
            r = out[name]
            print(f"serve,{name},rps,{r['requests_per_sec']:.1f},"
                  f"hit_rate,{r['deadline_hit_rate']:.3f},"
                  f"steps_p99,{r['steps_p99']:.0f}")
        print(f"serve,speedup,{out['speedup']:.2f}x,"
              f"threaded,{out['threaded_speedup']:.2f}x")
        tb = out["tight"]["batched"]
        print(f"serve,tight_{tight_deadline_ms}ms,batched_rps,"
              f"{tb['requests_per_sec']:.1f},hit_rate,"
              f"{tb['deadline_hit_rate']:.3f},steps_p50,{tb['steps_p50']:.0f},"
              f"steps_p99,{tb['steps_p99']:.0f}")
        for mode in ("reject", "degrade"):
            o = out["overload"][mode]
            print(f"serve,overload_{mode},hit_rate,{o['hit_rate']:.3f},"
                  f"rejected,{o['rejected']},degraded,"
                  f"{o['degraded_requests']},steps_p50,{o['steps_p50']:.0f}")
        for name, g in out["guaranteed"]["backends"].items():
            print(f"serve,guaranteed_{name},completed,{g['completed']}/"
                  f"{g['requests']},misses,{g['misses']},deadline_ms,"
                  f"{g['deadline_ms']:.1f},parity,"
                  f"{int(g['parity_vs_solo'])},rejected_infeasible,"
                  f"{g['rejected_infeasible']}")
        ob = out["obs"]
        print(f"serve,obs,disabled_ratio,{ob['disabled_ratio']:.3f},"
              f"traced_rps,{ob['traced_rps']:.1f},attributions,"
              f"{ob['attributions']},sum_fail,{ob['attribution_sum_fail']}")

    if gate:
        assert out["speedup"] >= min_speedup, (
            f"batched serving only {out['speedup']:.2f}x the serial loop "
            f"(gate: >= {min_speedup}x)")
        assert out["threaded_speedup"] >= min_speedup, (
            f"threaded serving only {out['threaded_speedup']:.2f}x the "
            f"serial loop (gate: >= {min_speedup}x)")
        for name in ("batched", "threaded"):
            assert out[name]["deadline_hit_rate"] >= min_hit_rate, (
                f"{name} deadline-hit-rate "
                f"{out[name]['deadline_hit_rate']:.3f} below gate "
                f"{min_hit_rate}")
        reject_hit = out["overload"]["reject"]["hit_rate"]
        degrade_hit = out["overload"]["degrade"]["hit_rate"]
        assert degrade_hit > reject_hit, (
            f"admission='degrade' hit-rate {degrade_hit:.3f} does not "
            f"dominate 'reject' {reject_hit:.3f} at equal load")
        gg = out["guaranteed"]
        assert gg["misses"] == 0 and gg["metrics_misses"] == 0, (
            f"guaranteed deadline misses: {gg['misses']} ticket-observed, "
            f"{gg['metrics_misses']} metrics-counted — a certified "
            f"admission admitted a request it could not deliver")
        assert gg["rejected_infeasible"] >= len(_GUARANTEED_BACKENDS), (
            f"certified admission rejected only "
            f"{gg['rejected_infeasible']} provably-infeasible deadlines "
            f"across {len(_GUARANTEED_BACKENDS)} backends — the pricing "
            f"gate is not firing")
        for name, g in gg["backends"].items():
            assert g["completed"] == g["requests"], (
                f"guaranteed {name}: only {g['completed']}/{g['requests']} "
                f"ran the full plan inside the certified deadline")
            assert g["parity_vs_solo"], (
                f"guaranteed {name} deliveries lost bit-parity with the "
                f"solo jnp-ref oracle")
            assert g["priced_bound_in_error"], (
                f"guaranteed {name}: CertificationFailed did not carry "
                f"the priced worst-case bound in its message")
        ob = out["obs"]
        assert ob["disabled_ratio"] >= min_trace_off_ratio, (
            f"disabled-tracer serving at {ob['disabled_ratio']:.2f}x the "
            f"untraced throughput (gate: >= {min_trace_off_ratio}x — the "
            f"trace=off fast path must stay within noise)")
        expected = len(rows) + min(capacity, len(rows))  # stream + warmup
        assert ob["attributions"] == expected, (
            f"traced run delivered {expected} requests (incl. warmup) but "
            f"produced {ob['attributions']} attribution records")
        assert ob["attribution_sum_fail"] == 0, (
            f"{ob['attribution_sum_fail']} attribution record(s) whose "
            f"components do not sum to end-to-end latency")
    return out


if __name__ == "__main__":
    run()
