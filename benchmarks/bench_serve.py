"""Serving benchmark + CI gate: batched deadline scheduling vs the
serial per-request session loop it replaces.

Two workloads over the same forest, order, and request stream:

* **complete** — generous deadlines, every request runs its full step
  order; isolates pure throughput (requests/sec).  This is the gated
  smoke workload: batched serving must deliver >= ``min_speedup`` x the
  serial loop's requests/sec with >= ``min_hit_rate`` deadline-hit-rate.
* **tight** — millisecond deadlines; reports the anytime quality
  profile under pressure (deadline-hit-rate, p50/p99
  steps-at-deadline, slot occupancy).

The serial baseline is the pre-``repro.serve`` deployment shape: one
fresh :class:`~repro.schedule.runtime.Session` per request, advanced
under its own deadline.  Each solo session closes over its own input
row, so every request re-traces its fused-segment dispatches — exactly
the per-request overhead the slot-batched scheduler amortizes across
``capacity`` concurrent requests (shared StepPlan, shared jit traces,
one masked dispatch for everyone).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_pipeline, runtime_for
from repro.serve import AnytimeServer


def _serial_loop(rt, order, rows, deadline_ms):
    """The pre-serve baseline: one session per request, own deadline."""
    steps = []
    t0 = time.perf_counter()
    for row in rows:
        sess = rt.session(row[None, :], order=order, backend="jnp-ref")
        sess.advance_until(deadline_ms)
        np.asarray(sess.predict_proba())  # deliver the anytime readout
        steps.append(sess.pos)
    dt = time.perf_counter() - t0
    steps = np.asarray(steps)
    return {
        "requests": len(rows),
        "wall_s": dt,
        "requests_per_sec": len(rows) / dt,
        "deadline_hit_rate": float((steps > 0).mean()),
        "steps_p50": float(np.percentile(steps, 50)),
        "steps_p99": float(np.percentile(steps, 99)),
    }


def _batched_loop(rt, rows, deadline_ms, capacity, warmup: bool = False):
    server = AnytimeServer(rt, capacity=capacity)
    if warmup:
        # compile the slot batch's fused-segment traces before timing —
        # millisecond deadlines are meaningless against cold jit compiles
        server.serve(list(rows[:capacity]), deadline_ms=300_000.0)
        server.metrics.reset()
    t0 = time.perf_counter()
    results = server.serve(list(rows), deadline_ms=deadline_ms)
    dt = time.perf_counter() - t0
    assert len(results) == len(rows)
    steps = np.asarray([r.steps_completed for r in results])
    snap = server.metrics.snapshot()
    return {
        "requests": len(rows),
        "wall_s": dt,
        "requests_per_sec": len(rows) / dt,
        "deadline_hit_rate": float(np.mean([r.deadline_hit for r in results])),
        "steps_p50": float(np.percentile(steps, 50)),
        "steps_p99": float(np.percentile(steps, 99)),
        "slot_occupancy": snap["slot_occupancy"],
        "dispatches": snap["dispatches"],
    }


def run(dataset: str = "magic", n_trees: int = 10, depth: int = 6,
        capacity: int = 16, n_requests: int = 48,
        tight_deadline_ms: float = 30.0, seed: int = 0,
        min_speedup: float = 3.0, min_hit_rate: float = 0.99,
        gate: bool = True, verbose: bool = True) -> dict:
    """Batched-vs-serial serving comparison; raises (failing the smoke
    build) when the gated thresholds are missed."""
    fa, pp, yor, te, yte = build_pipeline(
        dataset, n_trees, depth, seed=seed, n_order=200,
        n_test=max(n_requests, 64))
    rt = runtime_for(fa, pp, yor)
    order = rt.order("backward_squirrel")
    rows = te[:n_requests]
    generous = 300_000.0  # every request completes: pure throughput

    out = {"dataset": dataset, "n_trees": n_trees, "depth": depth,
           "capacity": capacity, "n_requests": n_requests,
           "total_steps": int(len(order))}
    out["serial"] = _serial_loop(rt, order, rows, generous)
    out["batched"] = _batched_loop(rt, rows, generous, capacity)
    out["speedup"] = (
        out["batched"]["requests_per_sec"] / out["serial"]["requests_per_sec"])
    # tight workload sized to capacity: the anytime-quality profile of
    # one in-flight generation (oversubscribed tight workloads measure
    # admission-control starvation instead — a different experiment)
    out["tight"] = {
        "deadline_ms": tight_deadline_ms,
        "serial": _serial_loop(rt, order, rows[:capacity], tight_deadline_ms),
        "batched": _batched_loop(rt, rows[:capacity], tight_deadline_ms,
                                 capacity, warmup=True),
    }

    if verbose:
        for name in ("serial", "batched"):
            r = out[name]
            print(f"serve,{name},rps,{r['requests_per_sec']:.1f},"
                  f"hit_rate,{r['deadline_hit_rate']:.3f},"
                  f"steps_p99,{r['steps_p99']:.0f}")
        print(f"serve,speedup,{out['speedup']:.2f}x")
        tb = out["tight"]["batched"]
        print(f"serve,tight_{tight_deadline_ms}ms,batched_rps,"
              f"{tb['requests_per_sec']:.1f},hit_rate,"
              f"{tb['deadline_hit_rate']:.3f},steps_p50,{tb['steps_p50']:.0f},"
              f"steps_p99,{tb['steps_p99']:.0f}")

    if gate:
        assert out["speedup"] >= min_speedup, (
            f"batched serving only {out['speedup']:.2f}x the serial loop "
            f"(gate: >= {min_speedup}x)")
        assert out["batched"]["deadline_hit_rate"] >= min_hit_rate, (
            f"deadline-hit-rate {out['batched']['deadline_hit_rate']:.3f} "
            f"below gate {min_hit_rate}")
    return out


if __name__ == "__main__":
    run()
