"""``python -m tools.perf`` — see :mod:`tools.perf.cli`."""
from tools.perf.cli import main

raise SystemExit(main())
