"""Kernel inefficiency-report suite (sibling of :mod:`tools.analyze`).

Wall-clock on this container is meaningless for kernel work — the Pallas
kernels run in interpret mode on CPU, where a Mosaic-compiled TPU launch
is emulated element-for-element.  What IS platform-independent is the
*analytical* cost of each implementation: how many kernel launches a
dispatched segment costs, how many node-table rows each step's gathers
address, and how many table bytes must sit resident in VMEM.  This
package computes those counters from the dispatch shapes alone (pure
stdlib — no jax import, so it runs in the lint/CI environment exactly
like ``tools.analyze``), renders them as a machine-readable report
(``reports/perf/kernels.json``) plus a human table, and gates CI on
them:

* ``python -m tools.perf``          — print the table;
* ``python -m tools.perf --write``  — regenerate the committed report;
* ``python -m tools.perf --check``  — recompute and fail (exit 1) on
  any counter regression vs the committed report, on a depth-aware
  variant that stopped strictly beating the full-width kernels on
  gather bytes/step, or on a tuning record selecting unknown impls.

``tools.perf.autotune`` (the only jax-importing module here, run as
``PYTHONPATH=src python -m tools.perf.autotune``) is the measured side:
it times every registered implementation per shape on the CURRENT
platform and persists the winners to ``tuning/<platform>.json`` — the
record :mod:`repro.kernels.ops` consults at dispatch time.
"""
