"""Command line entry point: ``python -m tools.perf``.

Pure stdlib (no jax) — runnable in the same environment as the lint
job.  Exit status under ``--check`` is 0 only when every counter gate
passes AND the committed ``reports/perf/kernels.json`` matches a fresh
recompute; the CI bench-smoke job runs exactly that.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.perf import report as report_mod


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.perf",
        description="Kernel inefficiency report: analytical launch/"
        "gather/residency counters per implementation, tuned-selection "
        "audit, CI counter gate.",
    )
    parser.add_argument(
        "--tuning-dir", default="tuning",
        help="directory of committed tuning/<platform>.json records",
    )
    parser.add_argument(
        "--report", default=str(report_mod.REPORT_PATH),
        help="committed report path (default: reports/perf/kernels.json)",
    )
    parser.add_argument(
        "--write", action="store_true",
        help="regenerate the committed report and exit 0",
    )
    parser.add_argument(
        "--check", action="store_true",
        help="gate: fail on counter regressions vs the committed report",
    )
    parser.add_argument(
        "--json", action="store_true",
        help="machine-readable JSON on stdout instead of the table",
    )
    args = parser.parse_args(argv)

    report = report_mod.build_report(Path(args.tuning_dir))
    report_path = Path(args.report)

    if args.write:
        report_mod.write_report(report, report_path)
        print(f"wrote {report_path}")
        return 0

    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(report_mod.render_table(report))

    if args.check:
        errors = report_mod.check_report(report, report_path)
        for e in errors:
            print(f"perf-check: {e}", file=sys.stderr)
        print(
            f"perf-check: {len(errors)} failure(s)"
            if errors else "perf-check: ok",
            file=sys.stderr,
        )
        return 1 if errors else 0
    return 0
