"""Analytical cost counters for every kernel implementation — pure
stdlib, valid on any platform (interpret mode included).

The model attributes one dispatched plan segment (``length`` fused
steps of one shape) to four platform-independent quantities:

* ``launches`` — Pallas kernel launches the dispatch costs (the
  per-launch overhead the fused kernels exist to amortize);
* ``gather_rows_per_step`` — node-table rows ADDRESSABLE by one step's
  gather per sample/slot row: the width of the one-hot contraction for
  the matmul-gather kernels, 1 for the true-gather jnp path.  This is
  the gather-pressure axis the depth-aware variant attacks;
* ``gather_bytes_per_step`` — the same in table bytes
  (``rows * NFIELDS * 4``);
* ``resident_bytes`` — the table footprint the kernel pins in VMEM for
  the whole launch (0 for non-resident/streaming paths).

The depth-aware width model uses the data-independent complete-tree
bound: after ``j`` root-relative steps at most ``2^(j+1) - 1`` nodes are
reachable, so the step-``j`` gather needs at most that many rows
(lane-rounded).  ``repro.kernels.layout.complete_tree_width`` implements
the SAME formula from real tables — a parity test pins the two together
and asserts real layouts never exceed the model.
"""
from __future__ import annotations

# mirrors repro.kernels.common (pure-stdlib copy; cross-checked by test)
NFIELDS = 8
LANE_ROUND = 128
WIDTH_LANES = 8
BYTES = 4  # all tables are f32
#: mirrors repro.kernels.ops.VMEM_TABLE_BUDGET_BYTES
DEFAULT_VMEM_BUDGET = 4 * 2**20

#: the implementation names the dispatch registries expose (a test pins
#: these to repro.kernels.tuning.SOLO_IMPLS/SLOT_IMPLS)
SOLO_IMPLS = ("fused", "scan", "depth")
SLOT_IMPLS = ("gather", "flat", "bucket", "cached")


def round_up(n: int, multiple: int) -> int:
    return -(-int(n) // multiple) * multiple


def pad_m(M: int) -> int:
    """Padded table height (mirrors ``common.pad_fields``)."""
    return round_up(max(int(M), 1), LANE_ROUND)


def complete_tree_width(step: int, m_padded: int,
                        lanes: int = WIDTH_LANES) -> int:
    """Upper bound on the depth-aware gather width at root-relative
    ``step``: a binary tree reaches at most ``2^(step+1) - 1`` nodes."""
    reachable = (1 << (step + 1)) - 1 if step < 62 else m_padded
    return min(m_padded, round_up(min(reachable, m_padded), lanes))


def depth_step_widths(length: int, m_padded: int,
                      levels: int | None = None) -> list[int]:
    """Per-step gather widths of a fresh depth-aware dispatch: narrow
    complete-tree-bounded widths while they stay below full width (capped
    at ``levels`` unrolled steps), full width for the tail."""
    widths = []
    for j in range(length):
        if levels is not None and j >= levels:
            widths.append(m_padded)
            continue
        w = complete_tree_width(j, m_padded)
        widths.append(w if w < m_padded else m_padded)
    return widths


def _counters(launches: int, rows_per_step: float, resident: int,
              length: int) -> dict:
    return {
        "launches": launches,
        "gather_rows_per_step": round(rows_per_step, 3),
        "gather_bytes_per_step": round(rows_per_step * NFIELDS * BYTES, 3),
        "resident_bytes": resident,
        "length": length,
    }


def solo_counters(impl: str, *, M: int, length: int,
                  levels: int | None = 4) -> dict:
    """Counters for one solo-path dispatch (index column [B], one tree).

    ``depth`` models the FRESH (root-start) dispatch — its only valid
    use; ``levels`` is the executor's unroll cap (None = unlimited).
    """
    Mp = pad_m(M)
    resident = Mp * NFIELDS * BYTES
    if impl == "fused":
        return _counters(1, Mp, resident, length)
    if impl == "scan":
        return _counters(length, Mp, resident, length)
    if impl == "depth":
        widths = depth_step_widths(length, Mp, levels)
        return _counters(1, sum(widths) / max(length, 1), resident, length)
    raise ValueError(f"unknown solo impl {impl!r} (have {SOLO_IMPLS})")


def slot_counters(impl: str, *, T: int, M: int, length: int,
                  top_rows: int = 32) -> dict:
    """Counters for one slot-path dispatch (index rows [S, T], per-slot
    tree ids).

    * ``gather`` — no kernel launch, a true 1-row gather per slot-step;
    * ``flat``   — one launch, whole forest resident, T*Mp-wide one-hot;
    * ``bucket`` — one launch, per-tree streamed tiles (resident_bytes
      counts only the single streamed tile), Mp-wide one-hot;
    * ``cached`` — one launch, flat tables + compacted top resident;
      the width model is conservative (full T*Mp — the narrow top path
      is data-dependent, so the analytical counter never credits it).
    """
    Mp = pad_m(M)
    tile = Mp * NFIELDS * BYTES
    if impl == "gather":
        return _counters(0, 1, 0, length)
    if impl == "flat":
        return _counters(1, T * Mp, T * tile, length)
    if impl == "bucket":
        return _counters(1, Mp, tile, length)
    if impl == "cached":
        top = min(max(int(top_rows), 1), Mp)
        return _counters(1, T * Mp, T * tile + T * top * NFIELDS * BYTES,
                         length)
    raise ValueError(f"unknown slot impl {impl!r} (have {SLOT_IMPLS})")


def fits_budget(resident_bytes: int,
                budget: int = DEFAULT_VMEM_BUDGET) -> bool:
    return resident_bytes <= budget
