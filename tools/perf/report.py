"""Build the kernel inefficiency report: analytical counters per shape
per implementation, plus what the committed tuning records select.

The report is deterministic (pure arithmetic over the benchmark shape
matrix + a JSON read of ``tuning/``), so the committed copy under
``reports/perf/kernels.json`` doubles as a regression baseline: --check
recomputes it and fails on ANY divergence — a counter that silently grew
(someone widened a gather), a tuning record selecting an unknown impl,
or a depth-aware variant that no longer strictly undercuts the
full-width kernels.
"""
from __future__ import annotations

import json
from pathlib import Path

from tools.perf import counters as C

REPORT_PATH = Path("reports/perf/kernels.json")

#: the shape matrix — mirrors benchmarks/bench_kernels.py configs
SOLO_SHAPES = [
    {"B": 128, "F": 16, "M": 127, "length": 32},
    {"B": 256, "F": 32, "M": 255, "length": 64},
]
SLOT_SHAPES = [
    {"S": 64, "T": 8, "M": 127, "F": 16, "length": 8},
    {"S": 128, "T": 12, "M": 255, "F": 32, "length": 16},
]

_DEFAULT_SOLO = "fused"
_DEFAULT_SLOT = "gather"


def _load_tuning_records(tuning_dir: Path) -> dict:
    """All committed ``tuning/<platform>.json`` records, by platform."""
    recs = {}
    if tuning_dir.is_dir():
        for p in sorted(tuning_dir.glob("*.json")):
            try:
                rec = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError, ValueError):
                rec = None
            if isinstance(rec, dict):
                recs[p.stem] = rec
    return recs


def _select(record: dict, kind: str, key: str) -> str:
    """Mirror of ``repro.kernels.tuning.select`` name resolution (pure
    JSON — no jax): exact key, then ``default``, then the conservative
    built-in.  Unlike the runtime (which degrades unknown names to the
    default at dispatch), this returns the record's RAW pick so
    ``check_report`` can flag a corrupt record instead of hiding it."""
    builtin = _DEFAULT_SOLO if kind == "solo" else _DEFAULT_SLOT
    section = record.get(kind, {}) if isinstance(record, dict) else {}
    if not isinstance(section, dict):
        section = {}
    entry = section.get(key) or section.get("default") or {}
    if not isinstance(entry, dict):
        entry = {}
    name = entry.get("impl", builtin)
    return name if isinstance(name, str) else builtin


def build_report(tuning_dir: Path = Path("tuning")) -> dict:
    records = _load_tuning_records(tuning_dir)
    solo_rows = []
    for shape in SOLO_SHAPES:
        Mp = C.pad_m(shape["M"])
        key = f"M{Mp}_L{shape['length']}"
        row = {
            "shape": dict(shape),
            "key": key,
            "impls": {
                name: C.solo_counters(
                    name, M=shape["M"], length=shape["length"]
                )
                for name in C.SOLO_IMPLS
            },
            "selected": {
                plat: _select(rec, "solo", key)
                for plat, rec in records.items()
            },
        }
        solo_rows.append(row)
    slot_rows = []
    for shape in SLOT_SHAPES:
        Mp = C.pad_m(shape["M"])
        key = f"T{shape['T']}_M{Mp}_L{shape['length']}"
        row = {
            "shape": dict(shape),
            "key": key,
            "impls": {
                name: C.slot_counters(
                    name, T=shape["T"], M=shape["M"], length=shape["length"]
                )
                for name in C.SLOT_IMPLS
            },
            "selected": {
                plat: _select(rec, "slot", key)
                for plat, rec in records.items()
            },
        }
        slot_rows.append(row)
    return {
        "schema": 1,
        "budget_bytes": C.DEFAULT_VMEM_BUDGET,
        "solo": solo_rows,
        "slot": slot_rows,
        "tuning_platforms": sorted(records),
    }


def render_table(report: dict) -> str:
    """The human table à la ``benchmarks/roofline_report.py``."""
    lines = [
        "| path | shape | impl | launches | gather rows/step | "
        "gather bytes/step | resident | fits | selected |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    budget = report["budget_bytes"]
    for kind in ("solo", "slot"):
        for row in report[kind]:
            s = row["shape"]
            if kind == "solo":
                shape = f"B{s['B']} M{s['M']} L{s['length']}"
            else:
                shape = f"S{s['S']} T{s['T']} M{s['M']} L{s['length']}"
            sel_by = {
                plat: name for plat, name in row.get("selected", {}).items()
            }
            for name, c in row["impls"].items():
                plats = ",".join(p for p, n in sel_by.items() if n == name)
                mark = f"**{plats}**" if plats else ""
                fits = "y" if C.fits_budget(c["resident_bytes"], budget) else "NO"
                lines.append(
                    f"| {kind} | {shape} | {name} | {c['launches']} | "
                    f"{c['gather_rows_per_step']:g} | "
                    f"{c['gather_bytes_per_step']:g} | "
                    f"{c['resident_bytes']} | {fits} | {mark} |"
                )
    return "\n".join(lines)


def check_report(report: dict, committed_path: Path = REPORT_PATH) -> list[str]:
    """The counter gates.  Returns a list of failure messages (empty =
    pass):

    1. depth-aware gather bytes/step STRICTLY below fused and scan on
       every solo shape (the PR's headline claim, kept true by math);
    2. bucketized gather bytes/step strictly below the flat slot kernel;
    3. every tuning-record selection resolves to a known impl whose
       resident footprint fits the VMEM budget;
    4. the committed report matches a fresh recompute (counters and
       selections are deterministic — divergence means someone changed
       the cost model or the tuning records without regenerating, or a
       real counter regression).
    """
    errors = []
    for row in report["solo"]:
        d = row["impls"]["depth"]["gather_bytes_per_step"]
        for other in ("fused", "scan"):
            o = row["impls"][other]["gather_bytes_per_step"]
            if not d < o:
                errors.append(
                    f"solo {row['key']}: depth gather bytes/step {d} not "
                    f"strictly below {other} ({o})"
                )
    for row in report["slot"]:
        b = row["impls"]["bucket"]["gather_bytes_per_step"]
        f = row["impls"]["flat"]["gather_bytes_per_step"]
        if not b < f:
            errors.append(
                f"slot {row['key']}: bucket gather bytes/step {b} not "
                f"strictly below flat ({f})"
            )
    budget = report["budget_bytes"]
    for kind in ("solo", "slot"):
        known = C.SOLO_IMPLS if kind == "solo" else C.SLOT_IMPLS
        for row in report[kind]:
            for plat, name in row.get("selected", {}).items():
                if name not in known:
                    errors.append(
                        f"{kind} {row['key']}: tuning[{plat}] selects "
                        f"unknown impl {name!r}"
                    )
                    continue
                c = row["impls"][name]
                if not C.fits_budget(c["resident_bytes"], budget):
                    errors.append(
                        f"{kind} {row['key']}: tuning[{plat}] selects "
                        f"{name} whose resident {c['resident_bytes']}B "
                        f"exceeds the {budget}B budget"
                    )
    if committed_path is not None:
        if not committed_path.exists():
            errors.append(
                f"no committed report at {committed_path} — run "
                f"`python -m tools.perf --write`"
            )
        else:
            try:
                committed = json.loads(committed_path.read_text())
            except (OSError, json.JSONDecodeError, ValueError):
                committed = None
            if committed != report:
                errors.append(
                    f"committed report {committed_path} diverges from "
                    f"recompute — counter regression, or regenerate with "
                    f"`python -m tools.perf --write`"
                )
    return errors


def write_report(report: dict, path: Path = REPORT_PATH) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
