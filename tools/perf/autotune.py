"""Measured per-platform kernel autotuner.

    PYTHONPATH=src python -m tools.perf.autotune [--out tuning/]

Times every registered implementation of both dispatch shapes
(:mod:`repro.kernels.tuning` registries) over the benchmark shape
matrix on the CURRENT platform, searching block sizes per impl, and
persists the winners to ``tuning/<platform>.json`` — the committed
record :mod:`repro.kernels.ops` consults at dispatch time.

Selection is deliberately biased toward the fallback: a kernel
implementation only wins its shape when it beats the conservative
baseline (``scan`` for solo, ``gather`` for slot) by at least
``WIN_MARGIN`` — measured-once wall-clock is noisy, and the dispatch
contract is that NO shape may regress vs the pre-kernel paths.  On CPU
the kernels run in interpret mode and lose by orders of magnitude, so a
CPU record honestly selects the fallbacks everywhere; on a TPU the same
search selects whichever kernel actually wins there.

This is the only jax-importing module in ``tools.perf`` — the report
and CLI stay pure stdlib.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, tuning
from tools.perf.report import SLOT_SHAPES, SOLO_SHAPES

#: a kernel must beat the conservative fallback by this factor to be
#: selected — absorbs run-to-run timing noise so the benchmark gate's
#: "selected is never slower" invariant holds on re-measurement
WIN_MARGIN = 1.15

_SOLO_FALLBACK = "scan"
_SLOT_FALLBACK = "gather"

#: per-impl search grids (impl -> list of extra kwarg dicts)
_SOLO_GRID = {
    "fused": [{"block_b": 128}, {"block_b": 256}],
    "scan": [{}],
}
_SLOT_GRID = {
    "gather": [{}],
    "flat": [{"block_s": 128}, {"block_s": 256}],
    "bucket": [{"block_s": 128}, {"block_s": 256}],
    "cached": [{"block_s": 256, "top_rows": 16},
               {"block_s": 256, "top_rows": 32}],
}


def _time(fn, *args, repeats: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def _solo_case(rng, shape):
    B, F, M = shape["B"], shape["F"], shape["M"]
    idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    tables = (
        jnp.asarray(rng.integers(0, F, size=M), jnp.int32),
        jnp.asarray(rng.normal(size=M), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.random(M) < 0.3),
    )
    return idx, X, tables


def _slot_case(rng, shape):
    S, T, M, F = shape["S"], shape["T"], shape["M"], shape["F"]
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = (
        jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32),
        jnp.asarray(rng.normal(size=(T, M)), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
        jnp.asarray(rng.random((T, M)) < 0.3),
    )
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.8)
    return idx, X, tables, units, mask


def _pick(timings: dict, fallback: str) -> tuple[str, dict, float]:
    """(impl, params, us) of the winner under the fallback-biased rule."""
    best_name, best_params, best_t = fallback, {}, timings[fallback][0][1]
    for name, runs in timings.items():
        for params, t in runs:
            if name == fallback:
                continue
            if t * WIN_MARGIN < best_t:
                best_name, best_params, best_t = name, params, t
    return best_name, best_params, best_t


def tune(verbose: bool = True) -> dict:
    rng = np.random.default_rng(7)
    record: dict = {
        "platform": jax.default_backend(),
        "generated_by": "tools.perf.autotune",
        "win_margin": WIN_MARGIN,
        "solo": {"default": {"impl": tuning.DEFAULT_SOLO_IMPL}},
        "slot": {"default": {"impl": tuning.DEFAULT_SLOT_IMPL}},
        # depth_levels is counter-justified (strictly fewer gather rows,
        # bit-exact), not wall-clock-gated; blocks mirror the solo winner
        "executor": {"depth_levels": 4, "block_b": 256, "block_m": 512},
    }
    for shape in SOLO_SHAPES:
        length = shape["length"]
        idx, X, tables = _solo_case(rng, shape)
        timings: dict = {}
        for name, grid in _SOLO_GRID.items():
            timings[name] = []
            for params in grid:
                fn = jax.jit(lambda i, x, *t, _n=name, _p=params: ops.forest_run(
                    i, x, *t, length=length, impl=_n, **_p))
                timings[name].append((params, _time(fn, idx, X, *tables)))
        name, params, t = _pick(timings, _SOLO_FALLBACK)
        key = tuning.solo_key(ops.round_up(max(shape["M"], 1), 128), length)
        record["solo"][key] = {"impl": name, **params,
                               "measured_us": round(t * 1e6, 1)}
        if verbose:
            print(f"autotune,solo,{key},winner,{name},{params},"
                  f"{t * 1e6:.0f}us")
    for shape in SLOT_SHAPES:
        length = shape["length"]
        idx, X, tables, units, mask = _slot_case(rng, shape)
        timings = {}
        for name, grid in _SLOT_GRID.items():
            timings[name] = []
            for params in grid:
                fn = jax.jit(lambda i, x, u, m, *t, _n=name, _p=params:
                             ops.slot_run(i, x, *t, u, m, length=length,
                                          impl=_n, **_p))
                timings[name].append(
                    (params, _time(fn, idx, X, units, mask, *tables))
                )
        name, params, t = _pick(timings, _SLOT_FALLBACK)
        key = tuning.slot_key(
            shape["T"], ops.round_up(max(shape["M"], 1), 128), length
        )
        record["slot"][key] = {"impl": name, **params,
                               "measured_us": round(t * 1e6, 1)}
        if verbose:
            print(f"autotune,slot,{key},winner,{name},{params},"
                  f"{t * 1e6:.0f}us")
    return record


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.perf.autotune",
        description="Measure kernel impls on this platform and persist "
        "the winners to tuning/<platform>.json.",
    )
    parser.add_argument("--out", default="tuning",
                        help="tuning-record directory (default: tuning/)")
    args = parser.parse_args(argv)
    record = tune()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{record['platform']}.json"
    path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    tuning.clear_cache()
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
