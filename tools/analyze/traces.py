"""Trace-budget checker: the ≤ 8-cached-jit-trace invariant.

The schedule layer bounds the number of distinct jit traces by routing
every *static* segment length through power-of-two bucketing
(``pow2_floor`` / ``pow2_decompose``), so lengths only ever take values
in ``{1, 2, 4, …, cap}``.  This checker verifies the routing statically:

* **unbucketed-length** — a call that mints a jit trace per distinct
  ``length`` (an ``executor.run(...)``-style call, a function jitted
  with a static argument named ``length``, or a kernel entry point with
  a keyword-only ``length``) must receive a length that is provably
  bucketed: a power-of-two literal, a direct ``pow2_floor(...)`` call, a
  local previously assigned from ``pow2_floor``, the loop variable of
  ``for p in pow2_decompose(...)``, or a parameter of the enclosing
  function (forwarding — the caller is checked at its own site).

* **jit-in-loop** — ``jax.jit(...)`` / ``functools.partial(jax.jit, …)``
  call sites and jit-decorated ``def``\\ s lexically inside a ``for`` /
  ``while`` body re-trace (or at best re-hash) per iteration; hoist them
  out of the loop.
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.analyze.core import (
    Config,
    Finding,
    SourceFile,
    attr_path,
    call_name,
    const_int,
    is_pow2,
)

CHECKER = "traces"

_BUCKET_FNS = {"pow2_floor", "pow2_decompose"}


def _is_jax_jit(node: ast.expr) -> bool:
    """``jax.jit`` / bare ``jit`` reference, or ``partial(jax.jit, …)``."""
    path = attr_path(node)
    if path in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call) and call_name(node) == "partial" and node.args:
        return attr_path(node.args[0]) in ("jax.jit", "jit")
    return False


def _static_param_names(fn: ast.FunctionDef, jit_call: ast.Call) -> set[str]:
    """Names of ``fn``'s parameters marked static in ``jit_call``."""
    params = [a.arg for a in fn.args.args]
    out: set[str] = set()
    for kw in jit_call.keywords:
        if kw.arg == "static_argnames":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.add(el.value)
        elif kw.arg == "static_argnums":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    if 0 <= el.value < len(params):
                        out.add(params[el.value])
    return out


def _jit_call_of(node: ast.expr) -> Optional[ast.Call]:
    """The ``jax.jit(..)``/``partial(jax.jit, ..)`` Call carrying the
    static-arg keywords, if ``node`` is one."""
    if isinstance(node, ast.Call):
        if attr_path(node.func) in ("jax.jit", "jit"):
            return node
        if call_name(node) == "partial" and node.args:
            if attr_path(node.args[0]) in ("jax.jit", "jit"):
                return node
    return None


def _discover_triggers(files: list[SourceFile], config: Config):
    """(function name, length-param position) pairs whose calls must
    receive bucketed lengths."""
    triggers: dict[str, Optional[int]] = {}  # name -> positional index (None = kw only)

    for sf in files:
        defs = {
            n.name: n
            for n in ast.walk(sf.tree)
            if isinstance(n, ast.FunctionDef)
        }
        for node in ast.walk(sf.tree):
            # `g = jax.jit(f, static_argnums=…)` wrapping a local def.
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                jc = _jit_call_of(node.value)
                if jc is not None and jc.args:
                    inner = jc.args[0] if attr_path(jc.func) else jc.args[-1]
                    fn = defs.get(attr_path(inner) or "")
                    if fn is not None and "length" in _static_param_names(fn, jc):
                        for tgt in node.targets:
                            tname = attr_path(tgt)
                            if tname:
                                params = [a.arg for a in fn.args.args]
                                idx = params.index("length") if "length" in params else None
                                triggers[tname.split(".")[-1]] = idx
            # jit-decorated defs with a static `length`.
            if isinstance(node, ast.FunctionDef):
                for deco in node.decorator_list:
                    jc = _jit_call_of(deco)
                    if jc is not None and "length" in _static_param_names(node, jc):
                        params = [a.arg for a in node.args.args]
                        idx = params.index("length") if "length" in params else None
                        triggers[node.name] = idx
            # kernel entry points with keyword-only `length`.
            if (
                isinstance(node, ast.FunctionDef)
                and config.kernels_prefix in sf.path
                and any(a.arg == "length" for a in node.args.kwonlyargs)
            ):
                triggers.setdefault(node.name, None)

    # Second pass: aliases of discovered triggers — the codebase binds
    # jitted closures onto instances (`self._generic_slots_jit = _generic_slots`).
    for sf in files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Name):
                src = node.value.id
                if src in triggers:
                    for tgt in node.targets:
                        tname = attr_path(tgt)
                        if tname:
                            triggers.setdefault(tname.split(".")[-1], triggers[src])
    return triggers


def _length_expr(call: ast.Call, pos: Optional[int]) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == "length":
            return kw.value
    if pos is not None and len(call.args) > pos:
        return call.args[pos]
    return None


class _FnContext:
    """Per-enclosing-function facts needed to judge a length expression."""

    def __init__(self, fn: Optional[ast.AST]):
        self.params: set[str] = set()
        self.bucketed: set[str] = set()
        if fn is None or not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        a = fn.args
        for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            self.params.add(arg.arg)
        if a.vararg:
            self.params.add(a.vararg.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name) and isinstance(node.value, ast.Call):
                    if call_name(node.value) in _BUCKET_FNS:
                        self.bucketed.add(tgt.id)
            if isinstance(node, ast.For) and isinstance(node.target, ast.Name):
                if (
                    isinstance(node.iter, ast.Call)
                    and call_name(node.iter) == "pow2_decompose"
                ):
                    self.bucketed.add(node.target.id)

    def length_ok(self, expr: ast.expr) -> bool:
        lit = const_int(expr)
        if lit is not None:
            return is_pow2(lit)
        if isinstance(expr, ast.Call) and call_name(expr) in _BUCKET_FNS:
            return True
        if isinstance(expr, ast.Name):
            return expr.id in self.bucketed or expr.id in self.params
        return False


def _enclosing_function_map(tree: ast.Module) -> dict[ast.AST, ast.AST]:
    """node -> nearest enclosing FunctionDef (or None)."""
    owner: dict[ast.AST, ast.AST] = {}

    def walk(node, fn):
        for child in ast.iter_child_nodes(node):
            owner[child] = fn
            walk(
                child,
                child
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef))
                else fn,
            )

    walk(tree, None)
    return owner


def check(files: list[SourceFile], config: Config) -> list[Finding]:
    findings: list[Finding] = []
    triggers = _discover_triggers(files, config)

    for sf in files:
        owner = _enclosing_function_map(sf.tree)
        ctx_cache: dict[Optional[ast.AST], _FnContext] = {}

        def fn_ctx(node) -> _FnContext:
            fn = owner.get(node)
            while fn is not None and not isinstance(
                fn, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                fn = owner.get(fn)
            if fn not in ctx_cache:
                ctx_cache[fn] = _FnContext(fn)
            return ctx_cache[fn]

        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            cname = call_name(node)
            expr = None
            site = None
            if cname in triggers:
                expr = _length_expr(node, triggers[cname])
                site = cname
            elif cname == "run":
                # executor.run(idx, units, mask, length, …) — the shared
                # trace-minting entry point.
                has_kw = any(kw.arg == "length" for kw in node.keywords)
                fpath = attr_path(node.func) or ""
                if has_kw or ("executor" in fpath and len(node.args) >= 4):
                    expr = _length_expr(node, 3)
                    site = fpath or "run"
            if expr is None:
                continue
            if not fn_ctx(node).length_ok(expr):
                findings.append(
                    Finding(
                        CHECKER,
                        "unbucketed-length",
                        sf.path,
                        node.lineno,
                        f"static `length` passed to {site}() is not routed "
                        f"through pow2_floor/pow2_decompose bucketing "
                        f"(got `{ast.unparse(expr)}`)",
                        symbol=f"{site}:L{node.lineno}",
                    )
                )

        # jit-in-loop retracing hazards.
        seen_loop_jits: set[int] = set()
        for loop in ast.walk(sf.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            for stmt in loop.body + loop.orelse:
                for node in ast.walk(stmt):
                    flagged = None
                    if isinstance(node, ast.Call) and _is_jax_jit(node.func):
                        flagged = node
                    elif isinstance(node, ast.Call) and _jit_call_of(node):
                        flagged = node
                    elif isinstance(node, ast.FunctionDef) and any(
                        _is_jax_jit(d) or _jit_call_of(d) is not None
                        for d in node.decorator_list
                    ):
                        flagged = node
                    if flagged is not None and flagged.lineno not in seen_loop_jits:
                        seen_loop_jits.add(flagged.lineno)
                        findings.append(
                            Finding(
                                CHECKER,
                                "jit-in-loop",
                                sf.path,
                                flagged.lineno,
                                "jax.jit closure created lexically inside a "
                                "loop body — hoist it out to avoid "
                                "per-iteration retracing",
                                symbol=f"L{flagged.lineno}",
                            )
                        )
    return findings
