"""Registry-coherence checker for ``@register_order`` / ``@register_backend``.

The schedule layer's policies and executors are discovered by name
through module-level registries.  This checker proves, per registry
kind:

* **duplicate-name** — no two registrations share a name (a later
  registration would silently shadow the earlier one);
* **missing-docstring** — every registered class documents itself (the
  registries feed ``--help``/docs listings);
* **missing-export** / **missing-all** — the defining module exports the
  registered class via ``__all__`` so the public surface matches the
  registry.

Registration sites are found statically, including the module-level
loops that stamp out families of orders::

    for _metric in PRUNE_METRICS:
        for _variant in ("depth", "breadth"):
            register_order(f"prune_{_variant}_{_metric}", …)(PruneOrder)

The loop iterables (inline tuples or module-level string-tuple
constants) are unrolled and the f-string names evaluated, so the
``prune_*``/``qwyc_*`` families are checked for collisions exactly like
decorator registrations.
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.analyze.core import Config, Finding, SourceFile, call_name

CHECKER = "registry"

_REGISTER_FNS = {
    "register_order",
    "register_backend",
    # kernel implementation registries (repro.kernels.tuning): dispatch
    # adapters the tuning records select between
    "register_solo_impl",
    "register_slot_impl",
    # serving admission policies (repro.serve.admission): overload
    # behavior the server resolves by name at construction
    "register_admission",
}


def _str_tuple_constants(sf: SourceFile) -> dict[str, tuple]:
    out: dict[str, tuple] = {}
    for stmt in sf.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name) and isinstance(
                stmt.value, (ast.Tuple, ast.List)
            ):
                vals = []
                for el in stmt.value.elts:
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        vals.append(el.value)
                    else:
                        break
                else:
                    out[tgt.id] = tuple(vals)
    return out


def _eval_name(node: ast.expr, env: dict[str, str]) -> Optional[str]:
    """A registration-name expression → its value: string literals and
    f-strings over loop variables bound in ``env``."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for piece in node.values:
            if isinstance(piece, ast.Constant):
                parts.append(str(piece.value))
            elif isinstance(piece, ast.FormattedValue) and isinstance(
                piece.value, ast.Name
            ):
                val = env.get(piece.value.id)
                if val is None:
                    return None
                parts.append(val)
            else:
                return None
        return "".join(parts)
    if isinstance(node, ast.Name):
        return env.get(node.id)
    return None


class _Registration:
    __slots__ = ("kind", "name", "target", "sf", "line")

    def __init__(self, kind, name, target, sf, line):
        self.kind = kind
        self.name = name
        self.target = target  # class name (str) or None
        self.sf = sf
        self.line = line


def _collect(sf: SourceFile) -> list[_Registration]:
    regs: list[_Registration] = []
    str_consts = _str_tuple_constants(sf)

    # Decorator registrations.
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.ClassDef, ast.FunctionDef)):
            continue
        for deco in node.decorator_list:
            if isinstance(deco, ast.Call) and call_name(deco) in _REGISTER_FNS:
                name = _eval_name(deco.args[0], {}) if deco.args else None
                regs.append(
                    _Registration(
                        call_name(deco), name, node.name, sf, deco.lineno
                    )
                )

    # Module-level call registrations, unrolling constant For loops.
    def scan(stmts, env):
        for stmt in stmts:
            if isinstance(stmt, ast.For) and isinstance(stmt.target, ast.Name):
                iterable = None
                if isinstance(stmt.iter, (ast.Tuple, ast.List)):
                    vals = [
                        el.value
                        for el in stmt.iter.elts
                        if isinstance(el, ast.Constant)
                        and isinstance(el.value, str)
                    ]
                    if len(vals) == len(stmt.iter.elts):
                        iterable = vals
                elif isinstance(stmt.iter, ast.Name):
                    iterable = str_consts.get(stmt.iter.id)
                if iterable:
                    for val in iterable:
                        scan(stmt.body, {**env, stmt.target.id: val})
                else:
                    scan(stmt.body, env)
            elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
                outer = stmt.value
                inner = outer.func
                if isinstance(inner, ast.Call) and call_name(inner) in _REGISTER_FNS:
                    name = _eval_name(inner.args[0], env) if inner.args else None
                    target = None
                    if outer.args and isinstance(outer.args[0], ast.Name):
                        target = outer.args[0].id
                    regs.append(
                        _Registration(
                            call_name(inner), name, target, sf, stmt.lineno
                        )
                    )

    scan(sf.tree.body, {})
    return regs


def _module_all(sf: SourceFile) -> Optional[set[str]]:
    for stmt in sf.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                if isinstance(stmt.value, (ast.Tuple, ast.List)):
                    return {
                        el.value
                        for el in stmt.value.elts
                        if isinstance(el, ast.Constant)
                        and isinstance(el.value, str)
                    }
    return None


def check(files: list[SourceFile], config: Config) -> list[Finding]:
    findings: list[Finding] = []
    seen: dict[tuple, _Registration] = {}
    regs_by_file: dict[str, list[_Registration]] = {}

    for sf in files:
        regs = _collect(sf)
        if regs:
            regs_by_file[sf.path] = regs

    for path in sorted(regs_by_file):
        for reg in regs_by_file[path]:
            if reg.name is None:
                findings.append(
                    Finding(
                        CHECKER,
                        "unresolvable-name",
                        reg.sf.path,
                        reg.line,
                        f"{reg.kind}() registration name is not statically "
                        f"resolvable — use a literal or a constant-tuple loop",
                        symbol=f"{reg.kind}:L{reg.line}",
                    )
                )
                continue
            key = (reg.kind, reg.name)
            if key in seen:
                prev = seen[key]
                findings.append(
                    Finding(
                        CHECKER,
                        "duplicate-name",
                        reg.sf.path,
                        reg.line,
                        f"{reg.kind}({reg.name!r}) already registered at "
                        f"{prev.sf.path}:{prev.line} — this registration "
                        f"silently shadows it",
                        symbol=f"{reg.kind}:{reg.name}",
                    )
                )
            else:
                seen[key] = reg

    # Per-target checks (docstring, export), deduplicated per target.
    # Kernel-impl registrations target FUNCTIONS; underscore-private
    # targets (the impl adapters — selected via the registry, never
    # imported) are exempt from the export checks but still need docs.
    for path in sorted(regs_by_file):
        sf = regs_by_file[path][0].sf
        classes = {
            n.name: n
            for n in ast.walk(sf.tree)
            if isinstance(n, (ast.ClassDef, ast.FunctionDef))
        }
        exported = _module_all(sf)
        targets = []
        for reg in regs_by_file[path]:
            if reg.target and reg.target not in [t for t, _ in targets]:
                targets.append((reg.target, reg))
        public_targets = [t for t in targets if not t[0].startswith("_")]
        if exported is None and public_targets:
            findings.append(
                Finding(
                    CHECKER,
                    "missing-all",
                    sf.path,
                    1,
                    f"module defines registered classes but no __all__ — "
                    f"the registry surface must be exported",
                    symbol=sf.module,
                )
            )
        for target, reg in targets:
            cls = classes.get(target)
            if cls is None:
                continue  # registered class imported from elsewhere
            if not ast.get_docstring(cls):
                kind = "class" if isinstance(cls, ast.ClassDef) else "function"
                findings.append(
                    Finding(
                        CHECKER,
                        "missing-docstring",
                        sf.path,
                        cls.lineno,
                        f"registered {kind} {target} has no docstring",
                        symbol=target,
                    )
                )
            if (exported is not None and target not in exported
                    and not target.startswith("_")):
                findings.append(
                    Finding(
                        CHECKER,
                        "missing-export",
                        sf.path,
                        cls.lineno,
                        f"registered class {target} is missing from __all__",
                        symbol=f"{sf.module}.{target}",
                    )
                )
    return findings
