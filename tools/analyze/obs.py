"""Observability hygiene checker: tracing-call discipline.

Three rules keep the :mod:`repro.obs` instrumentation sound:

* **span-without-with** — ``tracer.span(...)`` returns a context
  manager; calling it outside a ``with`` statement records an enter
  with no exit (the span never lands in the ring, and the thread-local
  active stack stays balanced only because ``__enter__`` never ran).
  Every ``.span(...)`` call on a tracer-ish receiver must be a
  ``with``-item.

* **trace-in-kernel** — Pallas kernel bodies (functions taking
  ``*_ref`` arguments) execute on-device via the Mosaic compiler;
  tracing calls there would either fail to lower or silently run at
  trace time only, recording garbage.  Instrumentation belongs at the
  dispatch layer (``ExecutorCore.run``), never inside a kernel body.

* **unknown-span-name** — span/instant/counter names are a closed
  registry (``repro.obs.names.SPAN_NAMES``): the committed trace schema
  enumerates them, so an unregistered literal name would export events
  that fail ``python -m tools.obs --check``.  Checked only when the
  analyzed file set contains the registry (fixture sets without it are
  exempt).
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.analyze.core import (
    Config,
    Finding,
    SourceFile,
    attr_path,
    call_name,
    import_map,
)

CHECKER = "obs"

#: tracer method names that record events
_TRACE_METHODS = {"span", "instant", "counter"}
#: names whose first positional argument is a registered span name
_NAMED_METHODS = {"span", "instant", "counter"}


def _tracer_receiver(node: ast.Call) -> bool:
    """Is this call's receiver tracer-ish (``tracer.span``,
    ``self.tracer.instant``, ``self._tracer.counter``, …)?"""
    path = attr_path(node.func)
    if path is None or "." not in path:
        return False
    owner = path.rsplit(".", 1)[0].rsplit(".", 1)[-1]
    return "tracer" in owner.lower()


def _span_name_registry(files: list[SourceFile]) -> Optional[set[str]]:
    """Keys of ``SPAN_NAMES`` if the registry module is in the analyzed
    set; None otherwise (rule 3 then stays silent)."""
    for sf in files:
        if not sf.path.endswith("obs/names.py"):
            continue
        for stmt in sf.tree.body:
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and stmt.targets[0].id == "SPAN_NAMES"
                    and isinstance(stmt.value, ast.Dict)):
                return {
                    k.value for k in stmt.value.keys
                    if isinstance(k, ast.Constant)
                    and isinstance(k.value, str)
                }
    return None


def _with_item_calls(tree: ast.Module) -> set[int]:
    """ids of Call nodes used as ``with``-item context expressions."""
    out: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if isinstance(item.context_expr, ast.Call):
                    out.add(id(item.context_expr))
    return out


def _obs_call(node: ast.Call, imports: dict[str, str]) -> Optional[str]:
    """The tracing-API name this call invokes, or None.

    Catches both method calls on tracer-ish receivers and module-level
    helpers imported (possibly aliased) from ``repro.obs``.
    """
    cname = call_name(node)
    if cname in _TRACE_METHODS and _tracer_receiver(node):
        return cname
    if isinstance(node.func, ast.Name):
        target = imports.get(node.func.id, "")
        if target.startswith("repro.obs"):
            return target.rsplit(".", 1)[-1]
    return None


def check(files: list[SourceFile], config: Config) -> list[Finding]:
    findings: list[Finding] = []
    registry = _span_name_registry(files)

    for sf in files:
        imports = import_map(sf)
        with_calls = _with_item_calls(sf.tree)

        # kernel bodies: functions taking *_ref arguments in kernel files
        kernel_fns = []
        if config.kernels_prefix in sf.path:
            kernel_fns = [
                fn for fn in ast.walk(sf.tree)
                if isinstance(fn, ast.FunctionDef)
                and any(a.arg.endswith("_ref")
                        for a in (*fn.args.args, *fn.args.kwonlyargs))
            ]
        for fn in kernel_fns:
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                api = _obs_call(node, imports)
                if api is not None:
                    findings.append(Finding(
                        CHECKER, "trace-in-kernel", sf.path, node.lineno,
                        f"tracing call `{api}(...)` inside Pallas kernel "
                        f"body `{fn.name}` — kernel bodies lower through "
                        f"Mosaic; instrument the dispatch layer instead",
                        symbol=f"{fn.name}:{api}",
                    ))

        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            cname = call_name(node)
            if cname == "span" and _tracer_receiver(node) \
                    and id(node) not in with_calls:
                findings.append(Finding(
                    CHECKER, "span-without-with", sf.path, node.lineno,
                    "tracer.span(...) must be a `with` context item — a "
                    "bare call opens a span that never closes or records",
                    symbol=f"span:L{node.lineno}",
                ))
            if registry is not None and cname in _NAMED_METHODS \
                    and _tracer_receiver(node) and node.args:
                first = node.args[0]
                if isinstance(first, ast.Constant) \
                        and isinstance(first.value, str) \
                        and first.value not in registry:
                    findings.append(Finding(
                        CHECKER, "unknown-span-name", sf.path, node.lineno,
                        f"span name {first.value!r} is not registered in "
                        f"repro.obs.names.SPAN_NAMES — the exported trace "
                        f"would fail schema validation",
                        symbol=f"{cname}:{first.value}",
                    ))
    return findings
