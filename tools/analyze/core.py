"""Shared infrastructure for the ``tools.analyze`` checkers.

Everything here is pure stdlib (``ast`` + ``tokenize``): the analyzers
parse source text, never import the analyzed modules, so the suite runs
without JAX installed and cannot be skewed by import-time side effects.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import tokenize
from pathlib import Path
from typing import Iterable, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer diagnostic, stable enough to baseline.

    ``key`` identifies the finding across line churn: it is built from
    the checker/rule/path and a symbol (class.field, function name, …)
    rather than the line number whenever the checker can name one.
    """

    checker: str
    rule: str
    path: str
    line: int
    message: str
    symbol: str = ""

    @property
    def key(self) -> str:
        anchor = self.symbol if self.symbol else f"L{self.line}"
        return f"{self.checker}:{self.rule}:{self.path}:{anchor}"

    def to_dict(self) -> dict:
        return {
            "checker": self.checker,
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
            "key": self.key,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.checker}/{self.rule}] {self.message}"


@dataclasses.dataclass
class SourceFile:
    """One parsed source file: AST plus the comment map the annotation
    conventions (``guarded-by:`` / ``holds:`` / ``unguarded:``) live in."""

    path: str
    text: str

    def __post_init__(self):
        self.path = Path(self.path).as_posix()
        self.tree: ast.Module = ast.parse(self.text, filename=self.path)
        self.comments: dict[int, str] = _comment_map(self.text)

    @property
    def module(self) -> str:
        """Dotted module name, best effort (``src/repro/x.py`` →
        ``repro.x``) — used to resolve cross-module references."""
        parts = list(Path(self.path).with_suffix("").parts)
        if "src" in parts:
            parts = parts[parts.index("src") + 1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def comment(self, lineno: int) -> str:
        return self.comments.get(lineno, "")


def _comment_map(text: str) -> dict[int, str]:
    out: dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except tokenize.TokenError:  # unterminated construct: keep what we got
        pass
    return out


@dataclasses.dataclass
class Config:
    """Knobs the checkers share.  Paths are matched as posix substrings
    so in-memory fixtures can opt into per-layer rules by path."""

    #: files whose classes MUST annotate every field (guarded/unguarded)
    serve_prefix: str = "repro/serve/"
    #: files subject to the Pallas kernel hygiene checker
    kernels_prefix: str = "repro/kernels/"
    #: fallback VMEM budget when no analyzed file defines the constant
    vmem_budget_bytes: int = 4 * 2**20
    #: name of the module-level constant that overrides the budget
    vmem_budget_name: str = "VMEM_TABLE_BUDGET_BYTES"


# ---------------------------------------------------------------------------
# Small AST utilities shared by the checkers.
# ---------------------------------------------------------------------------


def attr_path(node: ast.AST) -> Optional[str]:
    """Dotted path of a Name/Attribute chain (``self._server._lock`` →
    that string); None for anything more complex."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Last path component of the called expression (``a.b.f()`` → ``f``)."""
    func = node.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def const_int(node: ast.AST, env: Optional[dict] = None) -> Optional[int]:
    """Fold an int-valued constant expression (literals, +-*//**<<, and
    names resolvable through ``env``); None when not statically known."""
    env = env or {}
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return int(node.value)
    if isinstance(node, ast.Name):
        val = env.get(node.id)
        return val if isinstance(val, int) else None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs, rhs = const_int(node.left, env), const_int(node.right, env)
        if lhs is None or rhs is None:
            return None
        op = node.op
        if isinstance(op, ast.Add):
            return lhs + rhs
        if isinstance(op, ast.Sub):
            return lhs - rhs
        if isinstance(op, ast.Mult):
            return lhs * rhs
        if isinstance(op, ast.FloorDiv):
            return lhs // rhs if rhs else None
        if isinstance(op, ast.Pow):
            return lhs**rhs if rhs >= 0 else None
        if isinstance(op, ast.LShift):
            return lhs << rhs
    return None


def module_int_constants(sf: SourceFile) -> dict[str, int]:
    """Module-level ``NAME = <int expr>`` assignments, constant-folded
    (later assignments win, matching execution order)."""
    env: dict[str, int] = {}
    for stmt in sf.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                val = const_int(stmt.value, env)
                if val is not None:
                    env[tgt.id] = val
    return env


def import_map(sf: SourceFile) -> dict[str, str]:
    """Local name → fully-qualified dotted target for module-level
    imports (``from repro.kernels import forest_run as _fused`` →
    ``{'_fused': 'repro.kernels.forest_run'}``)."""
    out: dict[str, str] = {}
    for stmt in ast.walk(sf.tree):
        if isinstance(stmt, ast.Import):
            for alias in stmt.names:
                out[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(stmt, ast.ImportFrom) and stmt.module and not stmt.level:
            for alias in stmt.names:
                out[alias.asname or alias.name] = f"{stmt.module}.{alias.name}"
    return out


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------


def load_sources(root) -> list[SourceFile]:
    root = Path(root)
    files = []
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" in p.parts:
            continue
        files.append(SourceFile(str(p), p.read_text()))
    return files


def analyze_sources(
    files: Iterable[SourceFile], config: Optional[Config] = None
) -> list[Finding]:
    """Run all five checkers over an in-memory file set (deterministic
    order: checker registration, then path, then line)."""
    # checker modules import lazily so `import tools.analyze` stays cheap
    from tools.analyze import locks, obs, registry, traces, vmem

    files = list(files)
    config = config or Config()
    findings: list[Finding] = []
    for checker in (locks.check, traces.check, vmem.check, registry.check,
                    obs.check):
        findings.extend(checker(files, config))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.symbol))
    return findings


def analyze_paths(root, config: Optional[Config] = None) -> list[Finding]:
    return analyze_sources(load_sources(root), config)
