"""``tools.analyze`` — repo-specific static analysis for the serving and
kernel invariants ruff cannot see.

Four AST-based checkers, each encoding an invariant the codebase already
promises (and, until now, only enforced dynamically):

* :mod:`tools.analyze.locks` — **lock discipline** for ``repro.serve``:
  fields declared ``# guarded-by: <lock>`` may only be touched inside
  ``with self.<lock>`` blocks (or an alias such as the Condition built
  over the same lock), in methods marked ``# holds: <lock>``, or in
  ``__init__``; every serve-layer field must be annotated either
  ``guarded-by`` or ``# unguarded: <reason>``.
* :mod:`tools.analyze.traces` — **jit trace budget**: static ``length``
  arguments of trace-minting call sites must be routed through the
  shared ``pow2_floor``/``pow2_decompose`` bucketing (the ≤ 8-trace
  invariant), and ``jax.jit`` closures must not be created inside loops
  (retracing hazard).
* :mod:`tools.analyze.vmem` — **Pallas kernel hygiene** for
  ``repro.kernels``: ``pallas_call`` VMEM residency estimated from
  BlockSpec shapes/dtypes must fit ``ops.VMEM_TABLE_BUDGET_BYTES`` or be
  reachable only behind a budget-checked fallback, and kernel bodies
  must not branch/loop in Python on tracer values.
* :mod:`tools.analyze.registry` — **registry coherence**: every
  ``@register_order``/``@register_backend`` target has a unique name, a
  docstring, and its module exports it via ``__all__``.

Run ``python -m tools.analyze [--json] [--baseline analyze-baseline.json]``.
Pure stdlib ``ast`` — no JAX import at any analyzer module load, so it
runs in seconds in the CI lint job.
"""
from tools.analyze.core import (
    Config,
    Finding,
    SourceFile,
    analyze_paths,
    analyze_sources,
    load_sources,
)

__all__ = [
    "Config",
    "Finding",
    "SourceFile",
    "analyze_paths",
    "analyze_sources",
    "load_sources",
]
