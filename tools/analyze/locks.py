"""Lock-discipline race detector for ``repro.serve``.

Convention (enforced statically, documented in the README):

* A field assigned in a method body declares its discipline with a
  trailing comment on (one of) its assignment statements::

      self._pending = {}      # guarded-by: _lock
      self._driver = None     # unguarded: snapshot reads; writes caller-serialized

  The guard spec is a dotted path; only its last component is matched
  (so ``_server._lock`` and ``AnytimeServer._lock`` both mean "the
  attribute named ``_lock``").  ``Condition`` objects constructed over a
  lock (``self._cond = threading.Condition(self._lock)``) are aliases:
  holding the condition *is* holding the lock.

* A guarded field may be read or written only

  - lexically inside ``with <expr>:`` where ``<expr>`` resolves (through
    local aliases such as ``srv = self._server``) to the guard or an
    alias of it, or
  - inside a function whose ``def`` line (or the line above it) carries
    ``# holds: <guard>``, or
  - inside ``__init__`` (construction happens-before publication).

* In files under the serve layer every ``self.<field>`` assignment must
  be annotated ``guarded-by`` or ``unguarded`` — fields holding the
  locks themselves (``threading.Lock/RLock/Condition/Event``) are
  exempt.

Known, deliberate under-approximations: call sites of ``# holds:``
methods are trusted, not verified; accesses through another object
(``other._pending``) are not tracked; nested functions inherit the
lexical ``with`` context of their definition site even though they may
run later.  These are documented in the README.
"""
from __future__ import annotations

import ast
import re
from typing import Optional

from tools.analyze.core import Config, Finding, SourceFile, attr_path, call_name

CHECKER = "locks"

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([\w.|]+)")
_UNGUARDED_RE = re.compile(r"#\s*unguarded:\s*(\S.*)")
_HOLDS_RE = re.compile(r"#\s*holds:\s*([\w.|]+)")

_LOCK_FACTORIES = {
    "Lock", "RLock", "Condition", "Event", "Semaphore", "BoundedSemaphore",
}


def _last(spec: str) -> str:
    return spec.split(".")[-1]


def _normalize(spec: str, aliases: dict[str, str]) -> set[str]:
    """A guard spec (possibly ``a|b`` alternatives) → canonical last
    components, with Condition-over-lock aliases collapsed."""
    out = set()
    for alt in spec.split("|"):
        last = _last(alt.strip())
        out.add(aliases.get(last, last))
    return out


class _FieldDecl:
    __slots__ = ("kind", "spec", "line")

    def __init__(self, kind: str, spec: str, line: int):
        self.kind = kind  # "guarded" | "unguarded" | "lock"
        self.spec = spec
        self.line = line


def _self_assign_target(stmt: ast.stmt) -> Optional[ast.Attribute]:
    """The ``self.<x>`` target of an Assign/AnnAssign/AugAssign, if any."""
    targets: list[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    for tgt in targets:
        if (
            isinstance(tgt, ast.Attribute)
            and isinstance(tgt.value, ast.Name)
            and tgt.value.id == "self"
        ):
            return tgt
    return None


def _collect_class(sf: SourceFile, cls: ast.ClassDef):
    """Field declarations and Condition→lock aliases for one class."""
    decls: dict[str, _FieldDecl] = {}
    first_assign: dict[str, int] = {}
    aliases: dict[str, str] = {}
    for method in cls.body:
        if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for stmt in ast.walk(method):
            if not isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                continue
            tgt = _self_assign_target(stmt)
            if tgt is None:
                continue
            name = tgt.attr
            first_assign.setdefault(name, stmt.lineno)
            value = getattr(stmt, "value", None)
            if isinstance(value, ast.Call) and call_name(value) in _LOCK_FACTORIES:
                decls.setdefault(name, _FieldDecl("lock", "", stmt.lineno))
                if call_name(value) == "Condition" and value.args:
                    src = attr_path(value.args[0])
                    if src and src.startswith("self."):
                        aliases[name] = _last(src)
            # a declaration may span lines (call-style initializers); its
            # annotation may sit on any of them
            comment = " ".join(
                sf.comment(ln)
                for ln in range(stmt.lineno, (stmt.end_lineno or stmt.lineno) + 1)
                if sf.comment(ln)
            )
            m = _GUARDED_RE.search(comment)
            if m and decls.get(name, _FieldDecl("lock", "", 0)).kind != "guarded":
                decls[name] = _FieldDecl("guarded", m.group(1), stmt.lineno)
                continue
            m = _UNGUARDED_RE.search(comment)
            if m and name not in decls:
                decls[name] = _FieldDecl("unguarded", m.group(1), stmt.lineno)
    return decls, first_assign, aliases


def _holds_specs(sf: SourceFile, fn: ast.AST) -> list[str]:
    specs = []
    for line in (fn.lineno, fn.lineno - 1):
        m = _HOLDS_RE.search(sf.comment(line))
        if m:
            specs.append(m.group(1))
    return specs


class _AccessChecker(ast.NodeVisitor):
    """Walk one method, tracking the lexical ``with``-acquired guard set
    and local aliases of ``self``-rooted paths."""

    def __init__(self, sf, cls_name, decls, aliases, findings):
        self.sf = sf
        self.cls_name = cls_name
        self.decls = decls
        self.aliases = aliases
        self.findings = findings
        self.held: list[str] = []  # canonical guard names currently held
        self.holds_depth = 0  # >0 inside a `# holds:` function
        self.local_paths: dict[str, str] = {}  # var -> dotted self path
        self.reported: set[tuple[str, int]] = set()

    # -- path resolution ---------------------------------------------------

    def _resolve(self, node: ast.AST) -> Optional[str]:
        path = attr_path(node)
        if path is None:
            return None
        head, _, rest = path.partition(".")
        if head in self.local_paths:
            base = self.local_paths[head]
            return f"{base}.{rest}" if rest else base
        return path

    # -- visitors ----------------------------------------------------------

    def visit_Assign(self, node: ast.Assign):
        # Track `srv = self._server`-style aliases for with-item matching.
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            resolved = self._resolve(node.value)
            if resolved and resolved.startswith("self."):
                self.local_paths[node.targets[0].id] = resolved
        self.generic_visit(node)

    def _with_guards(self, node) -> list[str]:
        acquired = []
        for item in node.items:
            resolved = self._resolve(item.context_expr)
            if resolved:
                last = _last(resolved)
                acquired.append(self.aliases.get(last, last))
        return acquired

    def visit_With(self, node: ast.With):
        acquired = self._with_guards(node)
        self.held.extend(acquired)
        for item in node.items:
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        for stmt in node.body:
            self.visit(stmt)
        del self.held[len(self.held) - len(acquired):]

    visit_AsyncWith = visit_With

    def _enter_function(self, node):
        specs = _holds_specs(self.sf, node)
        entered = 0
        for spec in specs:
            for canon in _normalize(spec, self.aliases):
                self.held.append(canon)
                entered += 1
        self.holds_depth += 1 if specs else 0
        self.generic_visit(node)
        self.holds_depth -= 1 if specs else 0
        if entered:
            del self.held[len(self.held) - entered:]

    visit_FunctionDef = _enter_function
    visit_AsyncFunctionDef = _enter_function

    def visit_Attribute(self, node: ast.Attribute):
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            decl = self.decls.get(node.attr)
            if decl is not None and decl.kind == "guarded":
                wanted = _normalize(decl.spec, self.aliases)
                if not (wanted & set(self.held)):
                    key = (node.attr, node.lineno)
                    if key not in self.reported:
                        self.reported.add(key)
                        self.findings.append(
                            Finding(
                                CHECKER,
                                "unguarded-access",
                                self.sf.path,
                                node.lineno,
                                f"{self.cls_name}.{node.attr} is "
                                f"guarded-by {decl.spec!r} but accessed "
                                f"outside `with` / `# holds:` scope",
                                symbol=f"{self.cls_name}.{node.attr}:L{node.lineno}",
                            )
                        )
        self.generic_visit(node)


def check(files: list[SourceFile], config: Config) -> list[Finding]:
    findings: list[Finding] = []
    for sf in files:
        in_serve = config.serve_prefix in sf.path
        for cls in [n for n in ast.walk(sf.tree) if isinstance(n, ast.ClassDef)]:
            decls, first_assign, aliases = _collect_class(sf, cls)
            if in_serve:
                for name, line in sorted(first_assign.items(), key=lambda kv: kv[1]):
                    if name not in decls:
                        findings.append(
                            Finding(
                                CHECKER,
                                "unannotated-field",
                                sf.path,
                                line,
                                f"{cls.name}.{name} has no `# guarded-by:` "
                                f"or `# unguarded:` annotation",
                                symbol=f"{cls.name}.{name}",
                            )
                        )
            if not any(d.kind == "guarded" for d in decls.values()):
                continue
            for method in cls.body:
                if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if method.name == "__init__":
                    continue
                checker = _AccessChecker(sf, cls.name, decls, aliases, findings)
                # Apply the method's own holds-markers, then walk its body
                # (visiting the def itself would re-read them; this keeps
                # nested defs handled by the visitor).
                specs = _holds_specs(sf, method)
                for spec in specs:
                    checker.held.extend(_normalize(spec, aliases))
                for stmt in method.body:
                    checker.visit(stmt)
    return findings
