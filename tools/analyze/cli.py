"""Command line entry point: ``python -m tools.analyze``.

Exit status is 0 when every finding is suppressed by the baseline (or
there are none), 1 otherwise — the CI lint job runs exactly this.

The baseline (``analyze-baseline.json``) is a list of finding keys with
per-entry justifications; stale entries (keys no longer produced) are
reported so the baseline shrinks over time instead of rotting:

    {
      "findings": [
        {"key": "locks:unguarded-access:…", "justification": "why"}
      ]
    }
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.analyze.core import analyze_paths


def _load_baseline(path: Path) -> dict[str, str]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    out = {}
    for entry in data.get("findings", []):
        out[entry["key"]] = entry.get("justification", "")
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="Repo-specific static analysis: lock discipline, "
        "jit trace budget, Pallas VMEM hygiene, registry coherence, "
        "tracing-call hygiene.",
    )
    parser.add_argument(
        "--root",
        default="src/repro",
        help="directory tree to analyze (default: src/repro)",
    )
    parser.add_argument(
        "--baseline",
        default=None,
        help="JSON baseline of suppressed finding keys",
    )
    parser.add_argument(
        "--write-baseline",
        action="store_true",
        help="write all current findings to --baseline and exit 0",
    )
    parser.add_argument(
        "--json",
        action="store_true",
        help="machine-readable JSON on stdout instead of text",
    )
    args = parser.parse_args(argv)

    root = Path(args.root)
    if not root.exists():
        print(f"error: no such directory: {root}", file=sys.stderr)
        return 2

    findings = analyze_paths(root)

    baseline_path = Path(args.baseline) if args.baseline else None
    baseline = _load_baseline(baseline_path) if baseline_path else {}

    if args.write_baseline:
        if baseline_path is None:
            print("error: --write-baseline requires --baseline", file=sys.stderr)
            return 2
        payload = {
            "findings": [
                {"key": f.key, "justification": "TODO: justify or fix"}
                for f in findings
            ]
        }
        baseline_path.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    unsuppressed = [f for f in findings if f.key not in baseline]
    live_keys = {f.key for f in findings}
    stale = sorted(k for k in baseline if k not in live_keys)

    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.to_dict() for f in unsuppressed],
                    "suppressed": len(findings) - len(unsuppressed),
                    "stale_baseline_keys": stale,
                },
                indent=2,
            )
        )
    else:
        for f in unsuppressed:
            print(f.render())
        if stale:
            print(
                f"note: {len(stale)} stale baseline entr"
                f"{'y' if len(stale) == 1 else 'ies'} (fixed findings — "
                f"remove from {baseline_path}):",
                file=sys.stderr,
            )
            for k in stale:
                print(f"  {k}", file=sys.stderr)
        n_sup = len(findings) - len(unsuppressed)
        summary = f"{len(unsuppressed)} finding(s)"
        if n_sup:
            summary += f", {n_sup} baseline-suppressed"
        print(summary)

    return 1 if unsuppressed else 0
