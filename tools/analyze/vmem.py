"""Pallas kernel hygiene checker for ``repro.kernels``.

Three rules, all computed from ``pl.pallas_call`` sites without
importing JAX:

* **oversized-resident** — BlockSpecs whose index map is constant (e.g.
  ``lambda b: (0, 0)``) pin their block in VMEM for the whole launch.
  When every dimension of such a block is statically known (literals,
  module constants like ``NFIELDS``, parameter defaults, ``min(…)``
  clamps), the f32 footprint is summed and checked against
  ``ops.VMEM_TABLE_BUDGET_BYTES`` (read from the analyzed source, not
  imported).

* **missing-budget-guard** — a resident BlockSpec with a *symbolic*
  dimension (``Mp``, ``T * Mp``, …) is unbounded at analysis time, so
  every path reaching the ``pallas_call`` must be dominated by a budget
  check (an ``if`` whose test mentions ``_tables_fit``/``…BUDGET…`` and
  whose body returns or raises).  The guard may live in the enclosing
  function itself or in every in-package caller (resolved through each
  file's import map, so ``ops.forest_run`` and the kernel-module
  ``forest_run`` stay distinct).  A kernel entry point with no in-scope
  callers produces no finding — the budget contract then belongs to the
  (external) caller, which this pass cannot see.

* **tracer-control-flow** — Python ``if``/``while``/``for`` on values
  derived from ``*_ref`` reads or ``pl.program_id`` inside a kernel body
  traces data-dependently and fails (or silently specializes) under
  Mosaic; use ``lax.cond``/``fori_loop``.  Static Python parameters
  (``length``, ``block_m``) are fine and not flagged.
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.analyze.core import (
    Config,
    Finding,
    SourceFile,
    attr_path,
    call_name,
    const_int,
    import_map,
    module_int_constants,
)

CHECKER = "vmem"

_F32_BYTES = 4
_GUARD_TOKENS = ("tables_fit", "BUDGET")


def _enclosing_fn_map(tree: ast.Module) -> dict[ast.AST, Optional[ast.FunctionDef]]:
    owner: dict[ast.AST, Optional[ast.FunctionDef]] = {}

    def walk(node, fn):
        for child in ast.iter_child_nodes(node):
            owner[child] = fn
            walk(child, child if isinstance(child, ast.FunctionDef) else fn)

    walk(tree, None)
    return owner


def _cross_module_env(sf: SourceFile, by_module: dict[str, SourceFile]) -> dict[str, int]:
    """Int constants visible in ``sf``: its own module-level ones plus
    any imported from other analyzed modules (``NFIELDS`` et al.)."""
    env = dict(module_int_constants(sf))
    for local, fq in import_map(sf).items():
        mod, _, name = fq.rpartition(".")
        src = by_module.get(mod)
        if src is not None and local not in env:
            val = module_int_constants(src).get(name)
            if val is not None:
                env[local] = val
    return env


def _fn_env(fn: Optional[ast.FunctionDef], base: dict[str, int]) -> dict[str, int]:
    """``base`` extended with parameter defaults and ``min(…)`` clamps —
    the idiom ``block_b = min(block_b, max(8, B))`` bounds ``block_b``
    by its (constant) default."""
    env = dict(base)
    if fn is None:
        return env
    args = fn.args
    for arg, default in zip(args.args[len(args.args) - len(args.defaults):],
                            args.defaults):
        val = const_int(default, env)
        if val is not None:
            env.setdefault(arg.arg, val)
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            val = const_int(default, env)
            if val is not None:
                env.setdefault(arg.arg, val)
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and isinstance(node.value, ast.Call)
            and call_name(node.value) == "min"
        ):
            bounds = [const_int(a, env) for a in node.value.args]
            known = [b for b in bounds if b is not None]
            if known:
                env[node.targets[0].id] = min(known)
    return env


def _block_specs(call: ast.Call) -> list[ast.Call]:
    return [
        n
        for n in ast.walk(call)
        if isinstance(n, ast.Call) and call_name(n) == "BlockSpec"
    ]


def _spec_parts(spec: ast.Call):
    """(shape elements, index_map lambda-or-None) of one BlockSpec."""
    shape = None
    index_map = None
    if spec.args:
        shape = spec.args[0]
    if len(spec.args) > 1:
        index_map = spec.args[1]
    for kw in spec.keywords:
        if kw.arg in ("block_shape",):
            shape = kw.value
        elif kw.arg == "index_map":
            index_map = kw.value
    dims: list[ast.expr] = []
    if isinstance(shape, (ast.Tuple, ast.List)):
        dims = list(shape.elts)
    elif shape is not None:
        dims = [shape]
    return dims, index_map


def _is_resident(index_map: Optional[ast.expr]) -> bool:
    """Constant index map ⇒ the same block is mapped at every grid step
    (VMEM-resident).  No index map ⇒ whole-array block: resident too."""
    if index_map is None:
        return True
    if not isinstance(index_map, ast.Lambda):
        return False
    body = index_map.body
    elts = body.elts if isinstance(body, (ast.Tuple, ast.List)) else [body]
    return all(const_int(e, {}) is not None for e in elts)


def _mentions_guard_token(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        if name and any(tok in name for tok in _GUARD_TOKENS):
            return True
    return False


def _has_dominating_guard(fn: Optional[ast.FunctionDef], target: ast.AST) -> bool:
    """An ``if <…tables_fit/BUDGET…>: return/raise`` earlier in ``fn``
    than ``target`` — the budget-checked fallback idiom."""
    if fn is None:
        return False
    for node in ast.walk(fn):
        if not isinstance(node, ast.If) or node.lineno >= target.lineno:
            continue
        if not _mentions_guard_token(node.test):
            continue
        if any(
            isinstance(s, (ast.Return, ast.Raise))
            for stmt in node.body
            for s in ast.walk(stmt)
        ):
            return True
    return False


def _call_sites(files, target_module: str, fname: str):
    """In-package call sites of ``target_module.fname``, resolved through
    each file's import map (module-aware: ``kops.forest_run`` and
    ``_fused.forest_run`` resolve to different functions)."""
    sites = []
    for sf in files:
        imap = import_map(sf)
        owner = None
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            hit = False
            if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
                if imap.get(func.value.id) == target_module and func.attr == fname:
                    hit = True
            elif isinstance(func, ast.Name):
                fq = imap.get(func.id)
                if fq == f"{target_module}.{fname}":
                    hit = True
                elif (
                    func.id == fname
                    and sf.module == target_module
                    and fq is None
                ):
                    hit = True
            if hit:
                if owner is None:
                    owner = _enclosing_fn_map(sf.tree)
                sites.append((sf, node, owner.get(node)))
    return sites


def _kernel_fn_name(call: ast.Call) -> Optional[str]:
    """Name of the kernel body passed to ``pallas_call`` (possibly via
    ``functools.partial(kernel, …)``)."""
    if not call.args:
        for kw in call.keywords:
            if kw.arg == "kernel":
                target = kw.value
                break
        else:
            return None
    else:
        target = call.args[0]
    if isinstance(target, ast.Call) and call_name(target) == "partial" and target.args:
        target = target.args[0]
    if isinstance(target, ast.Name):
        return target.id
    if isinstance(target, ast.Attribute):
        return target.attr
    return None


def _check_tracer_flow(sf: SourceFile, fn: ast.FunctionDef, findings):
    tainted = {
        a.arg
        for a in (*fn.args.posonlyargs, *fn.args.args, *fn.args.kwonlyargs)
        if a.arg.endswith("_ref")
    }
    # one propagation sweep per nesting level is plenty for kernel bodies
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                rhs_taint = any(
                    (isinstance(s, ast.Name) and s.id in tainted)
                    or (isinstance(s, ast.Call) and call_name(s) == "program_id")
                    for s in ast.walk(node.value)
                )
                if rhs_taint:
                    for tgt in node.targets:
                        for s in ast.walk(tgt):
                            if isinstance(s, ast.Name):
                                tainted.add(s.id)

    def taints(node: ast.AST) -> bool:
        return any(
            isinstance(s, ast.Name) and s.id in tainted for s in ast.walk(node)
        )

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)) and taints(node.test):
            kind = "if" if isinstance(node, ast.If) else "while"
            findings.append(
                Finding(
                    CHECKER,
                    "tracer-control-flow",
                    sf.path,
                    node.lineno,
                    f"Python `{kind}` on a tracer-derived value inside "
                    f"kernel body {fn.name}() — use lax.cond/lax.while_loop",
                    symbol=f"{fn.name}:L{node.lineno}",
                )
            )
        elif isinstance(node, ast.For) and taints(node.iter):
            findings.append(
                Finding(
                    CHECKER,
                    "tracer-control-flow",
                    sf.path,
                    node.lineno,
                    f"Python `for` over a tracer-derived value inside "
                    f"kernel body {fn.name}() — use lax.fori_loop",
                    symbol=f"{fn.name}:L{node.lineno}",
                )
            )


def check(files: list[SourceFile], config: Config) -> list[Finding]:
    findings: list[Finding] = []
    by_module = {sf.module: sf for sf in files}

    budget = config.vmem_budget_bytes
    for sf in files:
        val = module_int_constants(sf).get(config.vmem_budget_name)
        if val is not None:
            budget = val
            break

    for sf in files:
        if config.kernels_prefix not in sf.path:
            continue
        owner = _enclosing_fn_map(sf.tree)
        base_env = _cross_module_env(sf, by_module)
        checked_kernels: set[str] = set()
        for call in ast.walk(sf.tree):
            if not (isinstance(call, ast.Call) and call_name(call) == "pallas_call"):
                continue
            fn = owner.get(call)
            env = _fn_env(fn, base_env)

            const_bytes = 0
            symbolic_dims: list[str] = []
            for spec in _block_specs(call):
                dims, index_map = _spec_parts(spec)
                if not _is_resident(index_map) or not dims:
                    continue
                vals = [const_int(d, env) for d in dims]
                if all(v is not None for v in vals):
                    n = _F32_BYTES
                    for v in vals:
                        n *= v
                    const_bytes += n
                else:
                    symbolic_dims.append(ast.unparse(spec.args[0] if spec.args else spec))

            fname = fn.name if fn is not None else "<module>"
            if const_bytes > budget:
                findings.append(
                    Finding(
                        CHECKER,
                        "oversized-resident",
                        sf.path,
                        call.lineno,
                        f"resident BlockSpecs of pallas_call in {fname}() "
                        f"pin ~{const_bytes} bytes in VMEM, over the "
                        f"{budget}-byte table budget",
                        symbol=f"{fname}:oversized",
                    )
                )

            if symbolic_dims and not _has_dominating_guard(fn, call):
                # the contract moves to the callers: each in-package call
                # site must sit behind a budget-checked fallback.
                sites = (
                    _call_sites(files, sf.module, fn.name) if fn is not None else []
                )
                for csf, cnode, cfn in sites:
                    if not _has_dominating_guard(cfn, cnode):
                        cname = cfn.name if cfn is not None else "<module>"
                        findings.append(
                            Finding(
                                CHECKER,
                                "missing-budget-guard",
                                sf.path,
                                call.lineno,
                                f"{fname}() keeps unbounded blocks "
                                f"({', '.join(symbolic_dims)}) resident in "
                                f"VMEM but caller {csf.path}:{cnode.lineno} "
                                f"({cname}) has no budget-checked fallback",
                                symbol=f"{fname}<-{csf.module}.{cname}",
                            )
                        )

            kname = _kernel_fn_name(call)
            if kname and kname not in checked_kernels:
                checked_kernels.add(kname)
                kfn = next(
                    (
                        n
                        for n in ast.walk(sf.tree)
                        if isinstance(n, ast.FunctionDef) and n.name == kname
                    ),
                    None,
                )
                if kfn is not None:
                    _check_tracer_flow(sf, kfn, findings)
    return findings
