"""Trace analysis: attribution accounting + segment-latency calibration.

Operates on the exported Chrome trace JSON (``repro.obs.export``), not
on live tracer state — the committed schema pins that contract.  Two
products:

* the **deadline-budget attribution report** — where delivered
  requests' latency went (queue / dispatch / compile / harvest /
  slack), with the accounting invariant that components sum to the
  measured end-to-end latency within tolerance;
* the **segment-latency calibration table** — per-(backend, impl,
  pow2-length) dispatch-wall histograms, jit compiles tabulated apart
  from steady state.  This is the measured per-segment cost table
  ROADMAP item 3's WCET-certified admission consumes.
"""
from __future__ import annotations

import json
from pathlib import Path

from tools.obs import schema as schema_mod

REPORTS_DIR = Path("reports/obs")
SCHEMA_PATH = REPORTS_DIR / "serve_trace_schema.json"
SAMPLE_PATH = REPORTS_DIR / "serve_trace_sample.json"

#: attribution components, report order.  Kept in lockstep with
#: ``repro.obs.names.ATTRIBUTION_FIELDS`` (tools stay stdlib-only, so
#: the constant is mirrored here; tests assert the two match).
ATTRIBUTION_FIELDS = (
    "queue_ms", "dispatch_ms", "compile_ms", "harvest_ms", "slack_ms",
)

#: accounting tolerance: |sum(components) - latency| must stay within
#: max(SUM_TOL_MS, SUM_REL_TOL * latency) — one monotonic clock, but
#: components accumulate across span boundaries.
SUM_TOL_MS = 1.0
SUM_REL_TOL = 0.05


def load_trace(path: Path | str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def load_schema(path: Path | str = SCHEMA_PATH) -> dict:
    with open(path) as fh:
        return json.load(fh)


def _percentile(sorted_vals: list[float], q: float) -> float:
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def segment_histograms(trace_events: list[dict]) -> dict[str, dict]:
    """Recompute the per-(backend, impl, pow2-length) dispatch-latency
    table from raw trace events (``ts``/``dur`` in microseconds) —
    independently of the exporter's own ``otherData`` aggregation, so
    ``--check`` can cross-validate the two."""
    cells: dict[str, dict[str, list[float]]] = {}
    for ev in trace_events:
        if ev.get("name") != "serve.dispatch" or ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        backend = args.get("backend", "?")
        impl = args.get("impl", backend)
        key = f"{backend}/{impl}/L{args.get('length', 0)}"
        cell = cells.setdefault(key, {"steady": [], "compile": []})
        bucket = "compile" if args.get("compile") else "steady"
        cell[bucket].append(float(ev.get("dur", 0.0)) / 1e3)  # µs -> ms
    out: dict[str, dict] = {}
    for key in sorted(cells):
        steady = sorted(cells[key]["steady"])
        compile_ = cells[key]["compile"]
        out[key] = {
            "count": len(steady),
            "mean_ms": sum(steady) / len(steady) if steady else 0.0,
            "p50_ms": _percentile(steady, 0.50) if steady else 0.0,
            "p95_ms": _percentile(steady, 0.95) if steady else 0.0,
            "max_ms": max(steady) if steady else 0.0,
            "compile_count": len(compile_),
            "compile_mean_ms":
                sum(compile_) / len(compile_) if compile_ else 0.0,
        }
    return out


def attribution_failures(doc: dict, tol_ms: float = SUM_TOL_MS,
                         rel_tol: float = SUM_REL_TOL) -> list[str]:
    """Violations of the attribution accounting invariant."""
    failures: list[str] = []
    attributions = doc.get("otherData", {}).get("attributions", [])
    by_id = {}
    for rec in attributions:
        rid = rec.get("request_id")
        by_id[rid] = rec
        total = sum(float(rec.get(f, 0.0)) for f in ATTRIBUTION_FIELDS)
        latency = float(rec.get("latency_ms", 0.0))
        if abs(total - latency) > max(tol_ms, rel_tol * latency):
            failures.append(
                f"request {rid}: components sum to {total:.3f} ms but "
                f"latency is {latency:.3f} ms (tolerance "
                f"{max(tol_ms, rel_tol * latency):.3f} ms)")
        for f in ATTRIBUTION_FIELDS:
            if float(rec.get(f, 0.0)) < 0:
                failures.append(f"request {rid}: negative {f}")
    # every delivery the ring retained must have its attribution record
    # (only checkable when nothing was evicted)
    if doc.get("otherData", {}).get("dropped", 0) == 0:
        for ev in doc.get("traceEvents", []):
            if ev.get("name") == "serve.deliver":
                rid = ev.get("args", {}).get("request_id")
                if rid not in by_id:
                    failures.append(
                        f"delivery instant for request {rid} has no "
                        "attribution record")
    return failures


def histogram_failures(doc: dict) -> list[str]:
    """Exporter aggregation vs independent recompute from the events."""
    committed = doc.get("otherData", {}).get("segment_histograms", {})
    fresh = segment_histograms(doc.get("traceEvents", []))
    failures: list[str] = []
    if set(committed) != set(fresh):
        failures.append(
            f"histogram cells differ: exported {sorted(committed)} vs "
            f"recomputed {sorted(fresh)}")
        return failures
    for key, row in fresh.items():
        got = committed[key]
        for field in ("count", "compile_count"):
            if got.get(field) != row[field]:
                failures.append(
                    f"{key}: {field} exported {got.get(field)} != "
                    f"recomputed {row[field]}")
        for field in ("mean_ms", "p50_ms", "p95_ms", "max_ms",
                      "compile_mean_ms"):
            a, b = float(got.get(field, 0.0)), row[field]
            if abs(a - b) > max(1e-6, 1e-6 * abs(b)):
                failures.append(
                    f"{key}: {field} exported {a} != recomputed {b}")
    return failures


def check(doc: dict, schema: dict) -> list[str]:
    """Every gate ``--check`` enforces, as human-readable failures."""
    failures = [f"schema: {e}" for e in schema_mod.validate(doc, schema)]
    if failures:
        return failures  # structure is wrong; content checks would lie
    failures.extend(attribution_failures(doc))
    failures.extend(histogram_failures(doc))
    return failures


def summarize_attributions(doc: dict) -> dict:
    records = doc.get("otherData", {}).get("attributions", [])
    n = len(records)
    out = {"count": n}
    for field in ("latency_ms",) + ATTRIBUTION_FIELDS:
        vals = [float(r.get(field, 0.0)) for r in records]
        out[f"mean_{field}"] = sum(vals) / n if n else 0.0
    out["deadline_hits"] = sum(1 for r in records if r.get("deadline_hit"))
    return out


def render_report(doc: dict) -> str:
    lines: list[str] = []
    other = doc.get("otherData", {})
    summary = summarize_attributions(doc)
    n = summary["count"]
    lines.append("deadline-budget attribution "
                 f"({n} delivered, {summary['deadline_hits']} deadline hits)")
    if n:
        lat = summary["mean_latency_ms"]
        lines.append(f"  mean latency {lat:9.3f} ms")
        for field in ATTRIBUTION_FIELDS:
            v = summary[f"mean_{field}"]
            share = v / lat if lat > 0 else 0.0
            lines.append(
                f"  mean {field.removesuffix('_ms'):<9} {v:9.3f} ms"
                f"  ({share:5.1%})")
    lines.append("")
    lines.append("segment-latency calibration "
                 "(backend/impl/pow2-length, steady-state | compiles)")
    hist = other.get("segment_histograms", {})
    if not hist:
        lines.append("  (no dispatch spans in trace)")
    for key in sorted(hist):
        row = hist[key]
        lines.append(
            f"  {key:<28} n={row['count']:<5} "
            f"mean={row['mean_ms']:8.3f} p50={row['p50_ms']:8.3f} "
            f"p95={row['p95_ms']:8.3f} max={row['max_ms']:8.3f} ms"
            f"  | compiles n={row['compile_count']} "
            f"mean={row['compile_mean_ms']:.3f} ms")
    margins = sum(
        1 for ev in doc.get("traceEvents", [])
        if ev.get("name") == "serve.margin")
    lines.append("")
    lines.append(
        f"events: {other.get('event_count', 0)} recorded, "
        f"{other.get('dropped', 0)} dropped by the ring, "
        f"{margins} margin samples")
    return "\n".join(lines)
