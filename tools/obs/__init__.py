"""tools.obs — offline analysis of exported serving traces.

``python -m tools.obs report`` renders the deadline-budget attribution
summary and the per-(backend, impl, pow2-length) segment-latency
calibration table from a Chrome trace-event JSON exported by
:mod:`repro.obs`; ``python -m tools.obs --check`` is the CI gate —
schema validation against the committed
``reports/obs/serve_trace_schema.json`` plus the attribution-accounting
invariant (components sum to end-to-end latency within tolerance).

Pure stdlib by design: the tools operate on the EXPORTED trace file
(the contract the schema pins), never on live tracer objects, so they
run in the same jax-free environment as the lint job.
"""
