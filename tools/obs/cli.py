"""Command line entry point: ``python -m tools.obs``.

Pure stdlib (no jax) — runnable in the same environment as the lint
job.  ``report`` renders the attribution + calibration tables for a
trace; ``--check`` exits non-zero unless the trace validates against
the committed schema AND every attribution's components sum to its
end-to-end latency within tolerance (the CI bench-smoke job runs this
against a freshly exported trace and against the committed sample).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.obs import report as report_mod


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.obs",
        description="Serving-trace analysis: deadline-budget attribution "
        "report, per-(backend, impl, pow2-length) segment-latency "
        "calibration table, schema + accounting CI gate.",
    )
    parser.add_argument(
        "command", nargs="?", choices=["report"], default="report",
        help="what to do (default: report)",
    )
    parser.add_argument(
        "--trace", default=str(report_mod.SAMPLE_PATH),
        help="trace JSON to analyze "
        "(default: the committed sample, reports/obs/serve_trace_sample.json)",
    )
    parser.add_argument(
        "--schema", default=str(report_mod.SCHEMA_PATH),
        help="schema to validate against "
        "(default: reports/obs/serve_trace_schema.json)",
    )
    parser.add_argument(
        "--check", action="store_true",
        help="gate: fail unless the trace validates against the schema "
        "and attribution components sum to end-to-end latency",
    )
    parser.add_argument(
        "--json", action="store_true",
        help="machine-readable JSON summary on stdout instead of the table",
    )
    args = parser.parse_args(argv)

    trace_path = Path(args.trace)
    if not trace_path.exists():
        print(f"no trace at {trace_path}", file=sys.stderr)
        return 2
    doc = report_mod.load_trace(trace_path)

    if args.check:
        schema = report_mod.load_schema(Path(args.schema))
        failures = report_mod.check(doc, schema)
        if failures:
            print(f"tools.obs --check: {len(failures)} failure(s) "
                  f"in {trace_path}:")
            for f in failures:
                print(f"  FAIL {f}")
            return 1
        n = len(doc.get("otherData", {}).get("attributions", []))
        print(f"tools.obs --check: OK ({trace_path}: schema valid, "
              f"{n} attribution records sum within tolerance)")
        return 0

    if args.json:
        print(json.dumps({
            "attribution": report_mod.summarize_attributions(doc),
            "segment_histograms":
                doc.get("otherData", {}).get("segment_histograms", {}),
        }, indent=2, sort_keys=True))
    else:
        print(report_mod.render_report(doc))
    return 0
