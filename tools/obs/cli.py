"""Command line entry point: ``python -m tools.obs``.

Pure stdlib (no jax) — runnable in the same environment as the lint
job.  ``report`` renders the attribution + calibration tables for a
trace; ``calibrate`` folds one or more traces' steady-state segment
histograms into the persisted per-platform worst-case table
(``reports/obs/wcet_<platform>.json``) that certified admission prices
from; ``--check`` exits non-zero unless the trace validates against
the committed schema AND every attribution's components sum to its
end-to-end latency within tolerance AND every committed WCET table is
structurally sound (the CI bench-smoke job runs this against a freshly
exported trace and against the committed sample).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.obs import report as report_mod
from tools.obs import wcet as wcet_mod


def _check_wcet_tables(root: Path) -> tuple[int, list[str]]:
    """Validate every committed ``wcet_*.json`` under ``root``.
    Returns (tables seen, failures)."""
    failures: list[str] = []
    paths = sorted(root.glob("wcet_*.json")) if root.is_dir() else []
    for path in paths:
        try:
            with open(path) as fh:
                table = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"{path}: unreadable ({e})")
            continue
        failures.extend(f"{path}: {f}" for f in wcet_mod.wcet_failures(table))
    return len(paths), failures


def _calibrate(args) -> int:
    traces = args.trace or [str(report_mod.SAMPLE_PATH)]
    docs = []
    for trace in traces:
        path = Path(trace)
        if not path.exists():
            print(f"no trace at {path}", file=sys.stderr)
            return 2
        docs.append(report_mod.load_trace(path))
    table = wcet_mod.fold(docs, platform=args.platform, margin=args.margin)
    failures = wcet_mod.wcet_failures(table)
    if failures:
        print(f"tools.obs calibrate: folded table is not certifiable "
              f"({len(failures)} failure(s)):")
        for f in failures:
            print(f"  FAIL {f}")
        print("  hint: the traces must contain steady-state "
              "serve.dispatch AND serve.harvest spans (run the traced "
              "workload for a second pass after jit warmup)")
        return 1
    # provenance ride-along: which traces fed the fold.  Added AFTER
    # validation so fold outputs stay byte-identical between the tools
    # and repro sides.
    table["sources"] = [str(t) for t in traces]
    out = Path(args.out) if args.out else wcet_mod.wcet_path(args.platform)
    out.parent.mkdir(parents=True, exist_ok=True)
    with open(out, "w") as fh:
        json.dump(table, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"tools.obs calibrate: wrote {out} "
          f"({len(table['cells'])} cells, harvest n="
          f"{table['harvest']['count']}, margin {table['margin']})")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.obs",
        description="Serving-trace analysis: deadline-budget attribution "
        "report, per-(backend, impl, pow2-length) segment-latency "
        "calibration table, WCET-table calibration for certified "
        "admission, schema + accounting CI gate.",
    )
    parser.add_argument(
        "command", nargs="?", choices=["report", "calibrate"],
        default="report",
        help="what to do (default: report).  'calibrate' folds the "
        "given --trace file(s) into a per-platform worst-case table "
        "for repro.serve.CostModel",
    )
    parser.add_argument(
        "--trace", action="append", default=None,
        help="trace JSON to analyze; repeatable for 'calibrate' "
        "(default: the committed sample, "
        "reports/obs/serve_trace_sample.json)",
    )
    parser.add_argument(
        "--schema", default=str(report_mod.SCHEMA_PATH),
        help="schema to validate against "
        "(default: reports/obs/serve_trace_schema.json)",
    )
    parser.add_argument(
        "--platform", default=None,
        help="calibrate: platform tag the table is keyed by "
        "(cpu/gpu/tpu — what jax.default_backend() reports at serve "
        "time)",
    )
    parser.add_argument(
        "--margin", type=float, default=2.0,
        help="calibrate: worst-case headroom factor, wcet_ms = margin "
        "* observed steady max (default: 2.0)",
    )
    parser.add_argument(
        "--out", default=None,
        help="calibrate: output path "
        "(default: reports/obs/wcet_<platform>.json)",
    )
    parser.add_argument(
        "--check", action="store_true",
        help="gate: fail unless the trace validates against the schema, "
        "attribution components sum to end-to-end latency, and every "
        "committed reports/obs/wcet_*.json table is structurally sound",
    )
    parser.add_argument(
        "--json", action="store_true",
        help="machine-readable JSON summary on stdout instead of the table",
    )
    args = parser.parse_args(argv)

    if args.command == "calibrate":
        if not args.platform:
            print("calibrate requires --platform", file=sys.stderr)
            return 2
        return _calibrate(args)

    traces = args.trace or [str(report_mod.SAMPLE_PATH)]
    trace_path = Path(traces[0])
    if not trace_path.exists():
        print(f"no trace at {trace_path}", file=sys.stderr)
        return 2
    doc = report_mod.load_trace(trace_path)

    if args.check:
        schema = report_mod.load_schema(Path(args.schema))
        failures = report_mod.check(doc, schema)
        n_tables, wcet_fails = _check_wcet_tables(report_mod.REPORTS_DIR)
        failures = failures + wcet_fails
        if failures:
            print(f"tools.obs --check: {len(failures)} failure(s) "
                  f"in {trace_path}:")
            for f in failures:
                print(f"  FAIL {f}")
            return 1
        n = len(doc.get("otherData", {}).get("attributions", []))
        print(f"tools.obs --check: OK ({trace_path}: schema valid, "
              f"{n} attribution records sum within tolerance, "
              f"{n_tables} WCET table(s) structurally sound)")
        return 0

    if args.json:
        print(json.dumps({
            "attribution": report_mod.summarize_attributions(doc),
            "segment_histograms":
                doc.get("otherData", {}).get("segment_histograms", {}),
        }, indent=2, sort_keys=True))
    else:
        print(report_mod.render_report(doc))
    return 0
