"""Minimal JSON-Schema-subset validator (pure stdlib).

Supports exactly the keywords ``reports/obs/serve_trace_schema.json``
uses — ``type`` (plus lists of types), ``enum``, ``const``,
``required``, ``properties``, ``additionalProperties`` (``false`` or a
schema applied to non-listed properties), ``items``, ``minimum``,
``minItems``, and in-document ``$ref`` to ``#/definitions/...`` — so
the CI gate needs no third-party schema library.  Unknown keywords raise instead of silently passing: a schema
edit that drifts outside the supported subset must fail loudly, not
validate vacuously.
"""
from __future__ import annotations

from typing import Any

__all__ = ["validate", "SchemaError"]

_KNOWN_KEYWORDS = {
    "$schema", "$ref", "title", "description", "definitions",
    "type", "enum", "const", "required", "properties",
    "additionalProperties", "items", "minimum", "minItems",
}

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


class SchemaError(ValueError):
    """The schema itself is malformed or uses an unsupported keyword."""


def _type_ok(value: Any, name: str) -> bool:
    if name == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    if name == "number":
        return (isinstance(value, (int, float))
                and not isinstance(value, bool))
    py = _TYPES.get(name)
    if py is None:
        raise SchemaError(f"unsupported type name {name!r}")
    if py is dict or py is list:
        return isinstance(value, py)
    # bool is an int subclass: "string"/"boolean"/"null" stay exact
    return type(value) is py or (py is not bool and isinstance(value, py)
                                 and not isinstance(value, bool))


def _resolve_ref(ref: str, root: dict) -> dict:
    if not ref.startswith("#/"):
        raise SchemaError(f"only in-document refs supported, got {ref!r}")
    node: Any = root
    for part in ref[2:].split("/"):
        if not isinstance(node, dict) or part not in node:
            raise SchemaError(f"unresolvable $ref {ref!r}")
        node = node[part]
    return node


def validate(instance: Any, schema: dict, root: dict | None = None,
             path: str = "$") -> list[str]:
    """All violations of ``schema`` by ``instance`` (empty = valid)."""
    root = schema if root is None else root
    if "$ref" in schema:
        return validate(instance, _resolve_ref(schema["$ref"], root),
                        root, path)
    unknown = set(schema) - _KNOWN_KEYWORDS
    if unknown:
        raise SchemaError(
            f"unsupported schema keyword(s) at {path}: {sorted(unknown)}")

    errors: list[str] = []
    if "type" in schema:
        names = schema["type"]
        names = [names] if isinstance(names, str) else list(names)
        if not any(_type_ok(instance, n) for n in names):
            errors.append(
                f"{path}: expected type {'/'.join(names)}, "
                f"got {type(instance).__name__}")
            return errors  # structural checks below would just cascade
    if "enum" in schema and instance not in schema["enum"]:
        errors.append(f"{path}: {instance!r} not in enum {schema['enum']}")
    if "const" in schema and instance != schema["const"]:
        errors.append(f"{path}: {instance!r} != const {schema['const']!r}")
    if "minimum" in schema and isinstance(instance, (int, float)) \
            and not isinstance(instance, bool) \
            and instance < schema["minimum"]:
        errors.append(f"{path}: {instance} < minimum {schema['minimum']}")

    if isinstance(instance, dict):
        props = schema.get("properties", {})
        for name in schema.get("required", ()):
            if name not in instance:
                errors.append(f"{path}: missing required property {name!r}")
        for name, sub in props.items():
            if name in instance:
                errors.extend(
                    validate(instance[name], sub, root, f"{path}.{name}"))
        addl = schema.get("additionalProperties")
        if addl is False:
            extra = set(instance) - set(props)
            if extra:
                errors.append(
                    f"{path}: unexpected propert"
                    f"{'ies' if len(extra) > 1 else 'y'} {sorted(extra)}")
        elif isinstance(addl, dict):
            for name in sorted(set(instance) - set(props)):
                errors.extend(validate(
                    instance[name], addl, root, f"{path}.{name}"))

    if isinstance(instance, list):
        if "minItems" in schema and len(instance) < schema["minItems"]:
            errors.append(
                f"{path}: {len(instance)} items < minItems "
                f"{schema['minItems']}")
        if "items" in schema:
            for i, item in enumerate(instance):
                errors.extend(
                    validate(item, schema["items"], root, f"{path}[{i}]"))
    return errors
