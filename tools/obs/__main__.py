from tools.obs.cli import main

raise SystemExit(main())
