"""Worst-case execution-time table: fold, validate, locate.

The certification half of ROADMAP item 3 lives on two sides of the
export boundary.  :func:`repro.obs.export.worst_case_table` folds a
LIVE tracer's spans; this module folds EXPORTED Chrome trace JSON
(``traceEvents`` with ``ts``/``dur`` in microseconds) — pure stdlib,
runnable in the jax-free lint environment — into the *identical*
structure, so tests can cross-validate the two implementations cell by
cell.  ``python -m tools.obs calibrate`` drives :func:`fold` over one
or more trace files and persists the result at :func:`wcet_path`;
``python -m tools.obs --check`` gates every committed table through
:func:`wcet_failures`.

Table structure (schema_version 1)::

    {
      "schema_version": 1,
      "platform": "cpu",
      "margin": 2.0,
      "cells": {
        "<backend>/<impl>/L<len>": {count, mean_ms, p95_ms, max_ms,
                                    wcet_ms},   # steady samples only
        ...
      },
      "harvest": {count, mean_ms, max_ms, wcet_ms},
    }

``wcet_ms = margin * max_ms`` over steady-state samples — jit-compile
dispatches are excluded (they are warmup, not recurring cost), and a
cell with only compiles is dropped entirely.
"""
from __future__ import annotations

import math
import re
from pathlib import Path

REPORTS_DIR = Path("reports/obs")

#: every dispatch cell keys as ``<backend>/<impl>/L<pow2-length>``
CELL_KEY_RE = re.compile(r"^[^/]+/[^/]+/L\d+$")

SCHEMA_VERSION = 1


def wcet_path(platform: str, root: Path | str = REPORTS_DIR) -> Path:
    """Canonical committed location of one platform's table."""
    return Path(root) / f"wcet_{platform}.json"


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile — same rule as tools.obs.report."""
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def fold(docs, *, platform: str, margin: float = 2.0) -> dict:
    """Pool steady dispatch + harvest durations across exported trace
    docs into one WCET table.

    ``docs`` is an iterable of parsed trace JSON objects (each with a
    ``traceEvents`` list).  Durations pool across docs BEFORE the
    statistics, so folding two traces is the same as tracing one run
    twice as long.  Output structure is byte-identical to
    :func:`repro.obs.export.worst_case_table` on the same spans.
    """
    if margin < 1.0:
        raise ValueError(
            f"wcet margin must be >= 1 (a headroom factor), got {margin}")
    dispatch: dict[str, list[float]] = {}
    harvests: list[float] = []
    for doc in docs:
        for ev in doc.get("traceEvents", []):
            if ev.get("ph") != "X":
                continue
            name = ev.get("name")
            dur_ms = float(ev.get("dur", 0.0)) / 1e3  # µs -> ms
            if name == "serve.dispatch":
                args = ev.get("args", {})
                if args.get("compile"):
                    continue  # warmup, not recurring worst case
                backend = args.get("backend", "?")
                impl = args.get("impl", backend)
                key = f"{backend}/{impl}/L{args.get('length', 0)}"
                dispatch.setdefault(key, []).append(dur_ms)
            elif name == "serve.harvest":
                harvests.append(dur_ms)
    cells: dict[str, dict] = {}
    for key in sorted(dispatch):
        steady = sorted(dispatch[key])
        cells[key] = {
            "count": len(steady),
            "mean_ms": sum(steady) / len(steady),
            "p95_ms": _percentile(steady, 0.95),
            "max_ms": steady[-1],
            "wcet_ms": margin * steady[-1],
        }
    harvests.sort()
    harvest = {
        "count": len(harvests),
        "mean_ms": sum(harvests) / len(harvests) if harvests else 0.0,
        "max_ms": harvests[-1] if harvests else 0.0,
        "wcet_ms": margin * harvests[-1] if harvests else 0.0,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "platform": platform,
        "margin": margin,
        "cells": cells,
        "harvest": harvest,
    }


def _finite_positive(row: dict, field: str) -> bool:
    v = row.get(field)
    return isinstance(v, (int, float)) and math.isfinite(v) and v > 0


def wcet_failures(table: dict) -> list[str]:
    """Every structural gate a committed WCET table must pass, as
    human-readable failure strings (empty list = valid).  Unknown extra
    keys are tolerated — the contract is a floor, not a ceiling."""
    failures: list[str] = []
    if table.get("schema_version") != SCHEMA_VERSION:
        failures.append(
            f"schema_version must be {SCHEMA_VERSION}, "
            f"got {table.get('schema_version')!r}")
    platform = table.get("platform")
    if not isinstance(platform, str) or not platform:
        failures.append(f"platform must be a non-empty string, "
                        f"got {platform!r}")
    margin = table.get("margin")
    if not isinstance(margin, (int, float)) or margin < 1.0:
        failures.append(f"margin must be a number >= 1, got {margin!r}")
    cells = table.get("cells")
    if not isinstance(cells, dict) or not cells:
        failures.append("cells must be a non-empty object")
        cells = {}
    for key, row in cells.items():
        if not CELL_KEY_RE.match(key):
            failures.append(
                f"cell key {key!r} does not match <backend>/<impl>/L<len>")
        if not isinstance(row, dict):
            failures.append(f"cell {key}: must be an object")
            continue
        count = row.get("count")
        if not isinstance(count, int) or count < 1:
            failures.append(f"cell {key}: count must be an int >= 1, "
                            f"got {count!r}")
        for field in ("mean_ms", "p95_ms", "max_ms", "wcet_ms"):
            if not _finite_positive(row, field):
                failures.append(
                    f"cell {key}: {field} must be a finite positive "
                    f"number, got {row.get(field)!r}")
        if (_finite_positive(row, "max_ms")
                and _finite_positive(row, "wcet_ms")
                and row["wcet_ms"] < row["max_ms"]):
            failures.append(
                f"cell {key}: wcet_ms {row['wcet_ms']} below observed "
                f"max_ms {row['max_ms']}")
    harvest = table.get("harvest")
    if not isinstance(harvest, dict):
        failures.append("harvest must be an object")
    else:
        count = harvest.get("count")
        if not isinstance(count, int) or count < 1:
            failures.append(
                f"harvest: count must be an int >= 1, got {count!r} "
                "(a table without harvest samples cannot price the "
                "per-iteration lag)")
        for field in ("mean_ms", "max_ms", "wcet_ms"):
            if not _finite_positive(harvest, field):
                failures.append(
                    f"harvest: {field} must be a finite positive number, "
                    f"got {harvest.get(field)!r}")
    return failures
